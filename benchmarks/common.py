"""Benchmark helpers: timing, CSV emission, TPU projection.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (derived
carries the paper-comparable figure: Gbps, KReq/s, LoC, ...).

CPU wall time is NOT the paper's metric — the derived column projects TPU
throughput from the compiled HLO's per-call byte traffic (hlo_walk) against
v5e HBM bandwidth, and latency from the NoC cost model.  Both the measured
and projected figures are reported.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

from repro.launch import hlo_walk
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 5,
              carry: bool = False) -> float:
    """Median wall microseconds per call (CPU measurement).

    ``carry=True`` threads the first element of fn's return value back as
    the new first argument on every call — required when the first
    argument is donated (``jax.jit(..., donate_argnums=(0,))``): the old
    state's buffers die with each call, so re-passing them would fault."""
    args = list(args)

    def call():
        out = fn(*args)
        jax.block_until_ready(out)
        if carry:
            args[0] = out[0] if isinstance(out, tuple) else out

    for _ in range(warmup):
        call()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        call()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def hlo_traffic(fn: Callable, *args) -> hlo_walk.WalkResult:
    """Walk the compiled HLO of fn(*args) for per-call flops/bytes."""
    text = jax.jit(fn).lower(*args).compile().as_text()
    return hlo_walk.walk(text)


def tpu_projected_seconds(w: hlo_walk.WalkResult) -> float:
    """Roofline-projected per-call seconds on one v5e chip."""
    return max(w.flops / PEAK_FLOPS, w.hbm_bytes / HBM_BW,
               w.coll_link_bytes / ICI_BW)


def row(name: str, us: float, derived: str) -> str:
    line = f"{name},{us:.2f},{derived}"
    print(line)
    return line


def assert_no_host_callbacks(fn: Callable, *args) -> None:
    """Walk fn(*args)'s jaxpr (scan bodies included) and fail on any
    host-touching primitive — the zero-host-sync certification from
    tests/test_stream.py, shared by the benchmarks that gate on it."""
    closed = jax.make_jaxpr(fn)(*args)
    prims = set()

    def walk(jaxpr):
        for eq in jaxpr.eqns:
            prims.add(eq.primitive.name)
            for v in eq.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax.core.ClosedJaxpr):
                        walk(s.jaxpr)
                    elif isinstance(s, jax.core.Jaxpr):
                        walk(s)

    walk(closed.jaxpr)
    bad = prims & {"pure_callback", "io_callback", "debug_callback",
                   "infeed", "outfeed", "device_put"}
    if bad:
        raise RuntimeError(f"compiled path touches the host: {bad}")


def append_trajectory(path: str, entry: dict) -> None:
    """Append one timestamped result to a BENCH_*.json trajectory file.
    History is the point: every PR adds a point, nothing is overwritten.
    A pre-trajectory file (any other JSON shape) is preserved under a
    ``legacy`` key rather than discarded."""
    data = {"trajectory": []}
    if os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        if isinstance(old, dict) and isinstance(old.get("trajectory"),
                                                list):
            data = old
        else:
            data["legacy"] = old
    data["trajectory"].append({"ts": time.time(), **entry})
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
