"""RPC request-latency tail: direct-attached vs host-mediated LM serving.

The paper's headline claim is that terminating the network stack ON the
accelerator removes the host from the request path.  This benchmark
measures that end to end with the real wire format (eth/ip/udp/rpc):

  * **direct** — each MSG_LM_GENERATE frame is a one-frame `run_stream`
    window through the compiled serve stack: parse tiles -> `lm_serve`
    app tile (one on-device decode step against session KV state living
    in the scan carry) -> reply framed by the tx tiles, all one device
    program.  Latency = dispatch to reply-frame-ready.
  * **host-mediated** — the pre-tentpole baseline (exactly the
    examples/serve_rpc.py deployment): the device stack parses the frame,
    the host syncs the payload out, drives the ServeEngine through
    `LmServerApp.handle` (decode dispatch + host-side position updates +
    sync per step), and frames the reply on the CPU.

Reports p50/p99/p999 over N requests round-robined across sessions and
**appends** a trajectory entry to ``BENCH_rpc_tail.json`` (history is the
point — each PR adds a point, nothing is overwritten).

Gate (`make bench-rpc-tail` fails otherwise): direct p99 <= 0.5x the
host-mediated p99.  Also asserts the compiled direct path has zero host
callbacks/transfers in the scanned region (same jaxpr walk as
tests/test_stream.py).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (append_trajectory, assert_no_host_callbacks,
                               row)
from repro.apps import lm_server
from repro.configs.serve_smoke import MAX_SEQ, MAX_SESSIONS, serve_config
from repro.models import model
from repro.net import eth, frames as F, ipv4, rpc, udp
from repro.net.stack import UdpStack, rpc_serve_topology
from repro.serve.engine import ServeEngine

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
LM_PORT = 9400
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_rpc_tail.json")

@jax.jit
def _parse_rx(payload, length):
    """The host-mediated server's device-side ingest (the parse half of
    the stack, as in examples/serve_rpc.py) — the host then syncs the
    body out to drive the engine."""
    p, l, m = eth.parse(payload, length)
    p, l, m2, ok1 = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok2 = udp.parse(p, l, m)
    body, blen, rmeta, ok3 = rpc.parse(p, l)
    return body, blen, ok1 & ok2 & ok3


def _request_frame(session: int, req_id: int, prompt=()) -> bytes:
    return F.udp_rpc_frame(
        IP_C, IP_S, 5000 + session, LM_PORT,
        rpc.np_frame(rpc.MSG_LM_GENERATE, req_id,
                     lm_server.encode_request(session, 1, list(prompt))))


def _percentiles(lat_us):
    p50, p99, p999 = np.percentile(lat_us, [50.0, 99.0, 99.9])
    return {"n": len(lat_us), "p50_us": float(p50), "p99_us": float(p99),
            "p999_us": float(p999), "mean_us": float(np.mean(lat_us))}


def _assert_no_host_sync(stack, state, p, l):
    """Zero host transfers inside the compiled serve program (the
    acceptance assertion from tests/test_stream.py, applied here so the
    bench itself certifies what it measures)."""
    assert_no_host_callbacks(
        lambda st, pp, ll: stack.run_stream(st, pp, ll), state, p, l)


def measure(n_requests: int = 160, n_sessions: int = 4, warmup: int = 8,
            prompt_len: int = 6):
    cfg = serve_config()
    params = model.init_params(cfg, jax.random.key(0))
    prompts = [np.arange(1 + s, 1 + s + prompt_len, dtype=np.int32)
               for s in range(n_sessions)]

    # ---- direct-attached path --------------------------------------------
    eng_d = ServeEngine(cfg, params, max_sessions=MAX_SESSIONS,
                        max_seq=MAX_SEQ)
    smap = {100 + s: eng_d.new_session(prompts[s])
            for s in range(n_sessions)}
    lm = lm_server.make_tile(cfg, params, max_sessions=MAX_SESSIONS,
                             max_seq=MAX_SEQ)
    stack = UdpStack([lm], IP_S,
                     topo=rpc_serve_topology(
                         [("lm", "lm_serve", rpc.MSG_LM_GENERATE)]))
    state = stack.init_state()
    state["apps"]["lm"] = lm_server.adopt_engine(state["apps"]["lm"],
                                                 eng_d, smap)

    frames = [_request_frame(100 + (i % n_sessions), i)
              for i in range(warmup + n_requests)]
    width = max(len(f) for f in frames) + 8
    # pre-staged device windows (the NIC's DMA ring), one frame each
    windows = []
    for f in frames:
        p, l = F.to_batch([f], width)
        windows.append((jnp.asarray(p)[None], jnp.asarray(l)[None]))

    _assert_no_host_sync(stack, state, *windows[0])
    stream = stack.stream_fn()

    lat_d = []
    for i, (p, l) in enumerate(windows):
        t0 = time.perf_counter()
        state, outs = stream(state, p, l)
        jax.block_until_ready(outs["tx_len"])
        dt = time.perf_counter() - t0
        if i == 0:
            assert bool(np.asarray(outs["alive"]).ravel()[0]), \
                "direct serve reply dropped"
        if i >= warmup:
            lat_d.append(dt * 1e6)
    served = int(np.asarray(state["apps"]["lm"]["served"]))
    assert served == warmup + n_requests, \
        f"direct path served {served}/{warmup + n_requests} requests"

    # ---- host-mediated baseline ------------------------------------------
    eng_h = ServeEngine(cfg, params, max_sessions=MAX_SESSIONS,
                        max_seq=MAX_SEQ)
    app = lm_server.LmServerApp(eng_h)
    for s in range(n_sessions):
        app.session_map[100 + s] = eng_h.new_session(prompts[s])

    lat_h = []
    for i, (p, l) in enumerate(windows):
        t0 = time.perf_counter()
        body, blen, ok = _parse_rx(p[0], l[0])        # device stack parse
        req = bytes(np.asarray(body[0, :int(blen[0])]).tobytes())  # sync
        reply = app.handle(req)                       # engine + host syncs
        F.udp_rpc_frame(IP_S, IP_C, LM_PORT, 5000,    # host reply framing
                        rpc.np_frame(rpc.MSG_LM_GENERATE, i, reply))
        dt = time.perf_counter() - t0
        if i >= warmup:
            lat_h.append(dt * 1e6)
        assert lm_server.reply_error(reply) is None

    d, h = _percentiles(lat_d), _percentiles(lat_h)
    return {
        "n_requests": n_requests, "n_sessions": n_sessions,
        "arch": cfg.name, "direct": d, "host": h,
        "speedup_p50": h["p50_us"] / d["p50_us"],
        "speedup_p99": h["p99_us"] / d["p99_us"],
        "speedup_p999": h["p999_us"] / d["p999_us"],
    }


def run():
    r = measure()
    d, h = r["direct"], r["host"]
    out = [row("rpc_tail_lm_direct", d["p50_us"],
               f"p99={d['p99_us']:.0f}us p999={d['p999_us']:.0f}us"),
           row("rpc_tail_lm_host", h["p50_us"],
               f"p99={h['p99_us']:.0f}us p999={h['p999_us']:.0f}us "
               f"speedup_p99={r['speedup_p99']:.2f}x")]
    append_trajectory(OUT_PATH, r)
    if r["speedup_p99"] < 2.0:
        raise RuntimeError(
            f"direct p99 {d['p99_us']:.0f}us is not <= 0.5x host-mediated "
            f"p99 {h['p99_us']:.0f}us (speedup {r['speedup_p99']:.2f}x, "
            f"gate: >= 2x)")
    return out


if __name__ == "__main__":
    run()
