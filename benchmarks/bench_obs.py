"""Observability overhead: pull + push telemetry vs telemetry-only.

The tentpole's cost claim: the device-resident observability layer rides
the same `run_stream` scan as the dataplane with no host callbacks — so
the only acceptable price is a small amount of extra on-device
arithmetic.  This bench measures it across three configs:

  * **baseline** — `UdpStack(..., with_obs=False)`: the full production
    pipeline with fused per-tile telemetry counters, exactly the
    pre-observability streamed path.
  * **obs** — the default stack with the recorder enabled at the
    production sampling rate (1 in 2**6 frames) and histograms
    accumulating every frame of every batch.
  * **push** — obs plus the whole push side: `int_mirror` packing
    postcards at 1/64, the series ring closing windows, and the SLO
    watchdog evaluating one installed rule per batch.

All run identical UDP-echo windows through donated `run_stream`
dispatches.  Appends a trajectory entry to ``BENCH_obs.json`` and gates
(`make bench-obs` fails otherwise):

  * obs AND push streamed time within 10% of the telemetry-only
    baseline, and
  * zero host callbacks/transfers in either scanned region.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import (append_trajectory, assert_no_host_callbacks,
                               row)
from repro.apps import echo
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, udp_topology
from repro.obs import postcard, series, slo

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")
OVERHEAD_GATE = 0.10


def _enable_recorder(state, shift: int = 6):
    """Flip the runtime sampling knobs directly in state (what TRACE_SET
    stages through the management plane — the bench needs no mgmt port)."""
    obs = dict(state["telemetry"]["obs"])
    obs["ctrl"] = {"enable": jnp.ones((), jnp.int32),
                   "shift": jnp.full((), shift, jnp.int32)}
    state = dict(state)
    state["telemetry"] = dict(state["telemetry"])
    state["telemetry"]["obs"] = obs
    return state


def _enable_push(state, stack, shift: int = 6):
    """Recorder at 1/2**shift (gates postcard packing too), series window
    length, and one live SLO rule — what SLO_SET/TRACE_SET would stage."""
    state = _enable_recorder(state, shift)
    ser = dict(state["telemetry"]["series"])
    ser["win_len"] = jnp.asarray(8, jnp.int32)
    state["telemetry"]["series"] = ser
    node = stack.pipeline.order.index("ip_rx")
    s = dict(state["slo"])
    s["metric"] = s["metric"].at[0].set(series.M_DROPS)
    s["node"] = s["node"].at[0].set(node)
    s["thr_raise"] = s["thr_raise"].at[0].set(1 << 20)
    s["thr_clear"] = s["thr_clear"].at[0].set(1 << 19)
    s["enabled"] = s["enabled"].at[0].set(1)
    state["slo"] = s
    return state


def _push_stack():
    apps = [echo.make(port=7)]
    topo = udp_topology(apps)
    postcard.bind_mirror(topo, collector_ip=IP_C)
    slo.bind_watchdog(topo, collector_ip=IP_C)
    return UdpStack(apps, IP_S, topo=topo)


def measure(n_batches: int = 64, batch: int = 16, frame_payload: int = 64,
            repeats: int = 7, shift: int = 6):
    fr = F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                         rpc.np_frame(rpc.MSG_ECHO, 0,
                                      b"x" * frame_payload))
    frames = [fr] * batch
    width = len(fr) + 64
    arena = F.FrameArena(n_batches, batch, width)
    arena.fill(frames * n_batches)
    n_pkts = n_batches * batch

    def timed_window(stack, st, stream):
        arena.fill(frames * n_batches)
        t0 = time.perf_counter()
        st, outs = stream(st, jnp.asarray(arena.payload),
                          jnp.asarray(arena.length))
        jax.block_until_ready(outs)
        return st, time.perf_counter() - t0

    def build_baseline():
        return UdpStack([echo.make(port=7)], IP_S, with_obs=False)

    def build_obs():
        return UdpStack([echo.make(port=7)], IP_S)

    results = {}
    for name, build, armfn in (("baseline", build_baseline, None),
                               ("obs", build_obs, _enable_recorder),
                               ("push", _push_stack, _enable_push)):
        stack = build()
        st = stack.init_state()
        if armfn is not None:
            st = (armfn(st, stack, shift) if armfn is _enable_push
                  else armfn(st, shift))
            assert_no_host_callbacks(
                stack.run_stream, st,
                jnp.asarray(arena.payload), jnp.asarray(arena.length))
        stream = stack.stream_fn()
        st, _ = timed_window(stack, st, stream)        # compile + warm
        ts = []
        for _ in range(repeats):
            st, t = timed_window(stack, st, stream)
            ts.append(t)
        results[name] = min(ts)

    t_b, t_o, t_p = results["baseline"], results["obs"], results["push"]
    return {
        "n_batches": n_batches, "batch": batch, "frame_bytes": len(fr),
        "sample_shift": shift, "packets_per_window": n_pkts,
        "baseline_us": t_b * 1e6, "obs_us": t_o * 1e6, "push_us": t_p * 1e6,
        "baseline_pps": n_pkts / t_b, "obs_pps": n_pkts / t_o,
        "push_pps": n_pkts / t_p,
        "overhead": t_o / t_b - 1.0,
        "overhead_push": t_p / t_b - 1.0,
    }


def run():
    r = measure()
    out = [row("obs_udp_echo_baseline",
               r["baseline_us"] / r["packets_per_window"],
               f"cpu={r['baseline_pps']:.0f}pps"),
           row("obs_udp_echo_recorded",
               r["obs_us"] / r["packets_per_window"],
               f"cpu={r['obs_pps']:.0f}pps "
               f"overhead={100 * r['overhead']:.1f}%"),
           row("obs_udp_echo_push",
               r["push_us"] / r["packets_per_window"],
               f"cpu={r['push_pps']:.0f}pps "
               f"overhead={100 * r['overhead_push']:.1f}%")]
    append_trajectory(OUT_PATH, r)
    worst = max(r["overhead"], r["overhead_push"])
    if worst > OVERHEAD_GATE:
        raise RuntimeError(
            f"observability overhead {100 * worst:.1f}% exceeds the "
            f"{100 * OVERHEAD_GATE:.0f}% gate (recorder at "
            f"1/{2 ** r['sample_shift']} sampling + histograms + "
            f"postcards/series/watchdog)")
    return out


if __name__ == "__main__":
    run()
