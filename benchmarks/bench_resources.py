"""Paper Table 4: per-tile resource utilization analog.

The FPGA metric (LUTs/BRAM) becomes compiled-HLO footprint per tile:
instruction count, per-call FLOPs, and HBM bytes for each protocol tile at
a fixed batch — the 'area' each tile occupies in the compiled program."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.launch import hlo_walk
from repro.net import eth, frames as F, ipv4, tcp, udp

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
BATCH = 64


def _walk(fn, *args):
    text = jax.jit(fn).lower(*args).compile().as_text()
    w = hlo_walk.walk(text)
    n_instr = sum(text.count(op) for op in (" fusion(", " dot(",
                                            " dynamic-slice("))
    return w, n_instr


def run():
    out = []
    fr = F.udp_rpc_frame(IP_C, IP_S, 5000, 7, b"x" * 64)
    payload, length = F.to_batch([fr] * BATCH, 256)
    p, l = jnp.asarray(payload), jnp.asarray(length)

    tiles = {
        "eth_rx": lambda pp, ll: eth.parse(pp, ll),
        "ip_rx": lambda pp, ll: ipv4.parse(*eth.parse(pp, ll)[:2]),
        "udp_rx": lambda pp, ll: udp.parse(
            *(lambda a, b, m, ok: (a, b, m))(
                *ipv4.parse(*eth.parse(pp, ll)[:2])),),
    }
    for name, fn in tiles.items():
        w, n = _walk(fn, p, l)
        out.append(row(f"table4_{name}", 0,
                       f"instrs={n} bytes/pkt={w.hbm_bytes/BATCH:.0f} "
                       f"flops/pkt={w.flops/BATCH:.0f}"))

    # TCP RX engine (paper: 11672 LUTs vs 2984 for UDP RX processing)
    conn = tcp.init(local_ip=IP_S)
    frt = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=1, ack=0, flags=tcp.SYN)
    tp, tl = F.to_batch([frt] * 8, 256)

    def tcp_rx(c, pp, ll):
        a, b, m = eth.parse(pp, ll)
        a, b, m2, ok = ipv4.parse(a, b)
        m.update(m2)
        d, dl, m = tcp.parse_segment(a, b, m)
        return tcp.rx_batch(c, d, dl, m)
    w, n = _walk(tcp_rx, conn, jnp.asarray(tp), jnp.asarray(tl))
    out.append(row("table4_tcp_rx", 0,
                   f"instrs={n} bytes/pkt={w.hbm_bytes/8:.0f} "
                   f"flops/pkt={w.flops/8:.0f}"))
    return out


if __name__ == "__main__":
    run()
