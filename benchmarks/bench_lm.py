"""Beyond-paper: the LM roofline table — reads the dry-run artifacts and
prints every (arch x shape x mesh) cell's roofline terms (the §Roofline
deliverable; launch/roofline.py renders the same data as markdown)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run():
    out = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        name = rec["cell"].replace("__", "/")
        out.append(row(
            f"roofline_{name}", rec.get("compile_s", 0) * 1e6,
            f"bound={r['bottleneck']} t_c={r['t_compute_s']:.4f} "
            f"t_m={r['t_memory_s']:.4f} t_x={r['t_collective_s']:.4f} "
            f"frac={r['roofline_fraction']:.3f} "
            f"fits={rec['memory']['fits_16GiB']}"))
    if not out:
        out.append(row("roofline_missing", 0,
                       "run: python -m repro.launch.dryrun --all"))
    return out


if __name__ == "__main__":
    run()
