"""Paper Table 1: lines of configuration to insert a new service.

Counts the serialized declarative config (tiles + route entries) needed to
add each paper application — the exact metric the paper reports for its
XML tooling — plus the deadlock re-analysis result after insertion."""
from __future__ import annotations

from benchmarks.common import row
from repro.apps import echo, reed_solomon, vr_witness
from repro.core import analyze
from repro.net.stack import tcp_topology, udp_topology


def run():
    out = []
    # Reed-Solomon: 4 replica tiles added to the UDP stack
    base = udp_topology([echo.make(port=7)])
    with_rs = udp_topology([echo.make(port=7),
                            reed_solomon.make(port=9000, n_replicas=4)])
    rs_names = [f"rs.{i}" for i in range(4)]
    loc = with_rs.config_loc(rs_names)
    ok = analyze(with_rs).ok
    out.append(row("table1_loc_reed_solomon", 0,
                   f"loc={loc} deadlock_free={ok} (paper: 25+6 xml / 13 verilog)"))

    # VR witness: 4 shard tiles
    with_vr = udp_topology([vr_witness.make(base_port=9100, n_shards=4)])
    vr_names = [f"vr.{i}" for i in range(4)]
    loc = with_vr.config_loc(vr_names)
    ok = analyze(with_vr).ok
    out.append(row("table1_loc_vr_witness", 0,
                   f"loc={loc} deadlock_free={ok} (paper: 18+6k xml / 17)"))

    # TCP migration: two NAT tiles inserted between IP and TCP without
    # touching either protocol tile (the paper's headline flexibility claim)
    plain = tcp_topology(with_nat=False)
    with_nat = tcp_topology(with_nat=True)
    loc = with_nat.config_loc(["nat_rx", "nat_tx"])
    ok = analyze(with_nat).ok
    shared = {t.name for t in plain.tiles} & {t.name for t in with_nat.tiles}
    untouched = all(
        plain.tile(n).kind == with_nat.tile(n).kind for n in shared
        if n not in ("ip_rx", "tcp_tx"))  # only their route tables changed
    out.append(row("table1_loc_tcp_migration", 0,
                   f"loc={loc} deadlock_free={ok} protocols_untouched="
                   f"{untouched} (paper: 2x(34+6) xml / 2x15)"))

    # NAT inserted into the *UDP* stack via insert_on_path — the compiled
    # executor makes this a pure topology edit, so the metric is the same
    # config-LoC count as the paper's XML story
    nat_udp = udp_topology([echo.make(port=7)])
    nat_udp.dim_x += 1
    nat_udp.tile("udp_rx").x += 1
    nat_udp.tile("echo").x += 1
    nat_udp.insert_on_path("nat_rx", "nat_rx", 2, 0, "ip_rx", "udp_rx")
    loc = nat_udp.config_loc(["nat_rx"])
    ok = analyze(nat_udp).ok
    out.append(row("table1_loc_nat_into_udp", 0,
                   f"loc={loc} deadlock_free={ok} (topology-only insertion; "
                   "no tile function changed)"))
    return out


if __name__ == "__main__":
    run()
