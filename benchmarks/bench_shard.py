"""Sharded-dataplane gate: shard_map scale-out of the compiled pipeline.

Runs the RSS-replicated UDP echo stack (2 udp_rx lanes behind a
flow-hash dispatch) both unsharded and 8-way sharded on a host-simulated
device mesh, and certifies the scale-out claim three ways:

  * **bit-identity** — every shard's streamed egress equals the
    unsharded reference run over the same frame partition;
  * **no collectives** — the sharded HLO contains no all-reduce /
    all-gather / collective-permute / all-to-all: shards are fully
    independent, so per-device throughput is preserved under scale-out;
  * **zero host callbacks** — the per-shard scanned region never touches
    the host (same jaxpr walk as the stream/obs gates).

Gate: the *certified projected aggregate* throughput on S devices must
be >= 4x the single-device baseline.  On this box every "device" is a
forced host-platform device on ONE physical core, so sharded wall time
cannot beat the baseline; the certificates above are exactly what makes
the projection sound (S independent, collective-free, host-free programs
run concurrently on S real devices), so the projection is

    projected_pps = total_packets / (sharded_wall / S)

i.e. per-shard work divided by per-shard time, times S.  The measured
1-core wall figures are reported and recorded alongside it.

Run from the battery (1 visible device) this module re-launches itself
on a forced 8-device mesh via `repro.launch.hostmesh`; it prints a SKIP
row when the platform refuses the forcing.  APPENDS to BENCH_shard.json.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import append_trajectory, row

SHARDS = 8
N_BATCHES = 8
BATCH = 32
MAX_LEN = 256
MIN_SPEEDUP = 4.0
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_shard.json")

_SCRIPT = r"""
import json, time
import numpy as np
import jax, jax.numpy as jnp

from benchmarks.common import assert_no_host_callbacks
from repro.apps import echo
from repro.net import frames as F, rpc
from repro.net.shard import ShardedStream
from repro.net.stack import UdpStack, replicated_udp_topology

SHARDS, N_BATCHES, BATCH, MAX_LEN = %(shards)d, %(n_batches)d, %(batch)d, %(max_len)d
IP_S = F.ip("10.0.0.1")


def make_stack():
    apps = [echo.make(port=7)]
    topo = replicated_udp_topology(apps, n_rx=2, policy="flow_hash")
    return UdpStack(apps, IP_S, topo=topo, mgmt_port=9909)


stack = make_stack()
ss = ShardedStream(stack, shards=SHARDS)
arena = ss.make_arena(N_BATCHES, BATCH, MAX_LEN)

# one flow per client port; whole flows land on one shard (host-side RSS)
flows = {}
per_shard = N_BATCHES * BATCH
for f in range(SHARDS * 16):
    port = 5000 + f
    flows[port] = [
        F.udp_rpc_frame(F.ip("10.0.0.%%d" %% (2 + f %% 50)), IP_S, port, 7,
                        rpc.np_frame(rpc.MSG_ECHO, i, b"x" * 64))
        for i in range(per_shard // 16)]
counts = arena.fill_rss(flows)
assert all(c == per_shard for c in counts), counts
total = SHARDS * per_shard

# ---- certificates ---------------------------------------------------------
# zero host callbacks in the per-shard scanned region
assert_no_host_callbacks(stack.run_stream, stack.init_state(),
                         jnp.asarray(arena.payload[0]),
                         jnp.asarray(arena.length[0]))
print("CALLBACKS_OK")

# no cross-shard collectives in the sharded HLO
state0 = ss.init_state()
hlo = jax.jit(ss._sharded).lower(
    state0, jnp.asarray(arena.payload),
    jnp.asarray(arena.length)).compile().as_text()
banned = ("all-reduce", "all-gather", "collective-permute", "all-to-all")
found = [b for b in banned if b in hlo]
assert not found, "cross-shard collectives in sharded HLO: %%s" %% found
print("COLLECTIVES_OK")

# per-shard egress is bit-identical to the unsharded reference
state1 = ss.init_state()
state1, outs = ss.run_stream(state1, arena.payload, arena.length)
outs = jax.tree.map(np.asarray, outs)
for s in range(SHARDS):
    ref_stack = make_stack()
    rst, ref = ref_stack.run_stream(ref_stack.init_state(),
                                    jnp.asarray(arena.payload[s]),
                                    jnp.asarray(arena.length[s]))
    assert np.array_equal(np.asarray(ref["tx_payload"]),
                          outs["tx_payload"][s]), s
    assert np.array_equal(np.asarray(ref["tx_len"]), outs["tx_len"][s]), s
    assert np.array_equal(np.asarray(ref["alive"]), outs["alive"][s]), s
served = int(outs["alive"].sum())
print("BIT_IDENTICAL_OK served=%%d" %% served)


def wall(fn, state, p, l, iters=3):
    p, l = jnp.asarray(p), jnp.asarray(l)
    state, outs = fn(state, p, l)           # compile + warm
    jax.block_until_ready(outs)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        state, outs = fn(state, p, l)
        jax.block_until_ready(outs)
        best = min(best, time.perf_counter() - t0)
    return best


# single-device baseline: one stack streams the ENTIRE workload
base_stack = make_stack()
base_fn = base_stack.stream_fn()
flat_p = arena.payload.reshape(SHARDS * N_BATCHES, BATCH, MAX_LEN)
flat_l = arena.length.reshape(SHARDS * N_BATCHES, BATCH)
t_base = wall(base_fn, base_stack.init_state(), flat_p, flat_l)

# sharded: S forced host devices time-slicing one core
t_shard = wall(ss.stream_fn(), ss.init_state(), arena.payload,
               arena.length)

base_pps = total / t_base
wall_pps = total / t_shard
proj_pps = total / (t_shard / SHARDS)
print("RESULT " + json.dumps({
    "shards": SHARDS, "total_packets": total, "served": served,
    "base_wall_s": t_base, "shard_wall_s": t_shard,
    "base_pps": base_pps, "shard_wall_pps": wall_pps,
    "projected_aggregate_pps": proj_pps,
    "projected_speedup": proj_pps / base_pps,
}))
"""


def run():
    from repro.launch import hostmesh
    script = _SCRIPT % {"shards": SHARDS, "n_batches": N_BATCHES,
                        "batch": BATCH, "max_len": MAX_LEN}
    out = hostmesh.run_script(script, devices=SHARDS, timeout=1800,
                              cwd=os.path.join(os.path.dirname(__file__),
                                               ".."))
    if hostmesh.UNAVAILABLE in out.stdout:
        return [row("shard_scaleout", 0,
                    f"SKIP: cannot force {SHARDS} host devices")]
    if out.returncode != 0:
        raise RuntimeError(f"bench_shard subprocess failed:\n"
                           f"{out.stderr[-3000:]}")
    for marker in ("CALLBACKS_OK", "COLLECTIVES_OK", "BIT_IDENTICAL_OK"):
        if marker not in out.stdout:
            raise RuntimeError(f"certificate {marker} missing:\n"
                               f"{out.stdout}")
    result_line = [ln for ln in out.stdout.splitlines()
                   if ln.startswith("RESULT ")][-1]
    r = json.loads(result_line[len("RESULT "):])

    rows = [
        row("shard_baseline_1dev",
            r["base_wall_s"] * 1e6 / r["total_packets"],
            f"cpu={r['base_pps']:.0f}pps"),
        row(f"shard_scaleout_{r['shards']}dev",
            r["shard_wall_s"] * 1e6 / r["total_packets"],
            f"proj={r['projected_aggregate_pps']:.0f}pps "
            f"wall={r['shard_wall_pps']:.0f}pps "
            f"speedup={r['projected_speedup']:.2f}x "
            f"(certified: no collectives, no callbacks, bit-identical)"),
    ]
    append_trajectory(OUT_PATH, r)
    if r["projected_speedup"] < MIN_SPEEDUP:
        raise RuntimeError(
            f"certified aggregate is only {r['projected_speedup']:.2f}x "
            f"the single-device baseline (gate: >= {MIN_SPEEDUP}x)")
    return rows


if __name__ == "__main__":
    run()
