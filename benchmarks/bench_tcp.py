"""Paper Figure 7: TCP send/receive goodput vs payload size.

RX: batches of in-order data segments through the jitted engine —
per-batch (one dispatch per batch) and streamed (N batches under one
`lax.scan`, the run_stream execution shape).  TX: app_send + tx_emit
segment generation.  Derived: TPU-projected segments/s and goodput from
compiled HBM traffic."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import (append_trajectory, hlo_traffic, row,
                               time_call)
from repro.launch.hlo_analysis import HBM_BW
from repro.net import eth, frames as F, ipv4, tcp

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
BATCH = 32
STREAM_BATCHES = 16
SIZES = (64, 512, 1460)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_tcp.json")


def _rx_ready(conn, size):
    frames = []
    seq = 5001
    for i in range(BATCH):
        frames.append(F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=seq,
                                      ack=0, flags=tcp.ACK | tcp.PSH,
                                      payload=b"x" * size))
        seq += size
    payload, length = F.to_batch(frames, size + 80)
    return jnp.asarray(payload), jnp.asarray(length)


def _rx_fn(conn, payload, length):
    p, l, m = eth.parse(payload, length)
    p, l, m2, ok = ipv4.parse(p, l)
    m.update(m2)
    data, dlen, m = tcp.parse_segment(p, l, m)
    return tcp.rx_batch(conn, data, dlen, m)


def run():
    out = []
    traj = {}
    for size in SIZES:
        conn = tcp.init(max_conns=4, rx_buf=BATCH * size + 4096,
                        local_ip=IP_S)
        # establish
        syn = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=5000, ack=0,
                              flags=tcp.SYN)
        p0, l0 = F.to_batch([syn], size + 80)
        conn, r = _rx_fn(conn, jnp.asarray(p0), jnp.asarray(l0))
        iss = int(r["tcp_seq"][0])
        ackf = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=5001, ack=iss + 1,
                               flags=tcp.ACK)
        p1, l1 = F.to_batch([ackf], size + 80)
        conn, _ = _rx_fn(conn, jnp.asarray(p1), jnp.asarray(l1))

        p, l = _rx_ready(conn, size)
        fn = jax.jit(_rx_fn)
        us = time_call(fn, conn, p, l)
        w = hlo_traffic(_rx_fn, conn, p, l)
        proj_sps = HBM_BW / max(w.hbm_bytes / BATCH, 1)
        proj_gbps = proj_sps * size * 8 / 1e9
        cpu_sps = BATCH / (us / 1e6)
        out.append(row(f"fig7_tcp_rx_{size}B", us / BATCH,
                       f"proj={min(proj_gbps, 100.0):.1f}Gbps "
                       f"cpu={cpu_sps:.0f}segs"))

        # streamed RX: the same segment batch scanned STREAM_BATCHES
        # times device-resident (engine state as the scan carry)
        sfn = jax.jit(lambda c, pp, ll: jax.lax.scan(
            lambda cc, xs: _rx_fn(cc, xs[0], xs[1]), c, (pp, ll)))
        ps = jnp.stack([p] * STREAM_BATCHES)
        ls = jnp.stack([l] * STREAM_BATCHES)
        us_s = time_call(sfn, conn, ps, ls)
        n_segs = STREAM_BATCHES * BATCH
        stream_sps = n_segs / (us_s / 1e6)
        out.append(row(f"fig7_tcp_rx_{size}B_stream", us_s / n_segs,
                       f"cpu={stream_sps:.0f}segs "
                       f"speedup={stream_sps / cpu_sps:.2f}x"))

        # TX: stage + emit one MSS segment
        data = jnp.zeros((size,), jnp.uint8)
        conn2, _ = tcp.app_send(conn, 0, data, size)
        tx = jax.jit(lambda c: tcp.tx_emit(c, 0, mss=1460))
        us_tx = time_call(tx, conn2)
        out.append(row(f"fig7_tcp_tx_{size}B", us_tx,
                       f"cpu={1e6/us_tx:.0f}segs/s"))
        traj[f"rx_sps_{size}B"] = cpu_sps
        traj[f"rx_stream_sps_{size}B"] = stream_sps
        traj[f"tx_us_{size}B"] = us_tx
    append_trajectory(OUT_PATH, traj)
    return out


if __name__ == "__main__":
    run()
