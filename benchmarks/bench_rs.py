"""Paper Table 2: Reed-Solomon scaling 1->4 replicas.

Measured: CPU throughput of the RS app behind the stack with n replicas
(linear scale-out = the paper's claim).  Derived: per-instance TPU
projection from the kernel's compiled traffic (paper: 15 Gbps/instance,
62 Gbps at 4) and bytes-moved-per-op (the energy proxy)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (append_trajectory, hlo_traffic, row,
                               time_call)
from repro.apps import reed_solomon
from repro.kernels.rs_encode import ops as rs_ops
from repro.launch.hlo_analysis import HBM_BW
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
REQS = 16
OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_rs.json")


def run():
    out = []
    traj = {}
    rng = np.random.default_rng(0)
    # kernel-level projection (single instance)
    data = jnp.asarray(rng.integers(0, 256, (8, 65536), dtype=np.uint8))
    w = hlo_traffic(lambda d: rs_ops.rs_encode(d, use_pallas=False), data)
    in_bytes = 8 * 65536
    proj_gbps = HBM_BW / max(w.hbm_bytes, 1) * in_bytes * 8 / 1e9
    bytes_per_op = w.hbm_bytes / (in_bytes / 4096)   # per 4KiB request
    us_k = time_call(jax.jit(lambda d: rs_ops.rs_encode(d, use_pallas=False)),
                     data)
    out.append(row("table2_rs_kernel_1inst", us_k,
                   f"proj={proj_gbps:.1f}Gbps bytes/op={bytes_per_op:.0f}"))
    traj["kernel_us"] = us_k
    traj["kernel_proj_gbps"] = proj_gbps
    traj["kernel_bytes_per_op"] = bytes_per_op

    # stack-level linear scale-out, 1..4 replicas
    block = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    fr = F.udp_rpc_frame(IP_C, IP_S, 5000, 9000,
                         rpc.np_frame(rpc.MSG_RS_ENCODE, 0, block))
    payload, length = F.to_batch([fr] * REQS, 4400)
    p, l = jnp.asarray(payload), jnp.asarray(length)
    base_us = None
    for n in (1, 2, 3, 4):
        stack = UdpStack([reed_solomon.make(port=9000, n_replicas=n)], IP_S,
                         with_telemetry=False)
        state = stack.init_state()
        fn = jax.jit(lambda s, pp, ll: stack.rx_tx(s, pp, ll))
        us = time_call(fn, state, p, l)
        base_us = base_us or us
        speed = REQS * 4096 * 8 / (us / 1e6) / 1e9
        out.append(row(f"table2_rs_stack_{n}inst", us / REQS,
                       f"proj={proj_gbps * n:.1f}Gbps cpu={speed:.3f}Gbps "
                       f"scale={base_us / us * n:.2f}x"))
        traj[f"stack_{n}inst_us_per_req"] = us / REQS
        traj[f"stack_{n}inst_cpu_gbps"] = speed
    append_trajectory(OUT_PATH, traj)
    return out


if __name__ == "__main__":
    run()
