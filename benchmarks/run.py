"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_flexibility, bench_lm, bench_mgmt,
                            bench_migration, bench_obs, bench_rs,
                            bench_shard, bench_stream, bench_tcp,
                            bench_tcp_loss, bench_udp_echo, bench_vr,
                            bench_resources)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_flexibility, bench_udp_echo, bench_stream, bench_tcp,
                bench_tcp_loss, bench_rs, bench_vr, bench_migration,
                bench_mgmt, bench_obs, bench_shard, bench_resources,
                bench_lm):
        try:
            mod.run()
        except Exception:
            failures += 1
            print(f"{mod.__name__},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == "__main__":
    main()
