"""Paper Figure 9 / Table 3: VR witness latency & throughput, 1-4 shards.

Measured: CPU requests/s through the stack with port-match shard dispatch,
plus per-request service latency.  Derived: TPU projection from compiled
traffic and the NoC chain latency (the witness's reply latency floor)."""
from __future__ import annotations

import struct

import jax
import jax.numpy as jnp

from benchmarks.common import hlo_traffic, row, time_call
from repro.apps import vr_witness
from repro.core.noc import chain_latency_ns
from repro.launch.hlo_analysis import HBM_BW
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
REQS = 64


def _frames(n_shards):
    frames = []
    per = REQS // n_shards
    for s in range(n_shards):
        for i in range(per):
            body = struct.pack("!IIII", vr_witness.OP_PREPARE, 0, i + 1, 7)
            frames.append(F.udp_rpc_frame(
                IP_C, IP_S, 5000 + i, 9100 + s,
                rpc.np_frame(rpc.MSG_VR_PREPARE, i, body)))
    return F.to_batch(frames, 256)


def run():
    out = []
    for shards in (1, 2, 3, 4):
        stack = UdpStack([vr_witness.make(base_port=9100, n_shards=shards)],
                         IP_S, with_telemetry=False)
        state = stack.init_state()
        payload, length = _frames(shards)
        p, l = jnp.asarray(payload), jnp.asarray(length)
        fn = jax.jit(lambda s, pp, ll: stack.rx_tx(s, pp, ll))
        us = time_call(fn, state, p, l)
        w = hlo_traffic(lambda s, pp, ll: stack.rx_tx(s, pp, ll), state, p, l)
        proj_rps = HBM_BW / max(w.hbm_bytes / REQS, 1)
        out.append(row(f"fig9_vr_{shards}shard", us / REQS,
                       f"proj={proj_rps/1e3:.0f}kOps cpu={REQS/(us/1e6):.0f}rps"))
    lat = chain_latency_ns([(0, 0), (1, 0), (2, 0), (3, 0), (2, 1), (1, 1),
                            (0, 1)], payload_bytes=16)
    out.append(row("table3_vr_latency_floor", lat / 1000,
                   f"noc_chain={lat:.0f}ns"))
    return out


if __name__ == "__main__":
    run()
