"""Management-plane contention check (paper §3.6: control runs on its own
NoC and "never contends" with the dataplane).

Measures the compiled UDP echo pipeline's per-batch cost on a
management-bound stack three ways: pure data traffic, data with 1%
management commands interleaved (the paper's operating regime), and
management-only batches (ack latency).  The derived column reports the 1%
interleave overhead vs pure data — the regression check: it should stay
within noise, since management frames ride the same batch and the ctrl
NoC adds no dataplane stages."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import append_trajectory, row, time_call
from repro.apps import echo
from repro.core import control
from repro.mgmt.console import command_frame
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
MGMT_PORT = 9909
BATCH = 100          # 1 management frame = 1% of the batch
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_mgmt.json")


def _batches():
    data = [F.udp_rpc_frame(IP_C, IP_S, 5000 + i, 7,
                            rpc.np_frame(rpc.MSG_ECHO, i, b"x" * 64))
            for i in range(BATCH)]
    mgmt = command_frame(IP_C, IP_S, 5999, MGMT_PORT,
                         control.OP_LOG_READ, a=0, b=0, req_id=1)
    mixed = data[:BATCH - 1] + [mgmt]
    mgmt_only = [command_frame(IP_C, IP_S, 5999, MGMT_PORT,
                               control.OP_VERSION, req_id=i)
                 for i in range(BATCH)]
    out = {}
    for name, frames in (("pure", data), ("mixed", mixed),
                         ("mgmt", mgmt_only)):
        p, l = F.to_batch(frames, 256)
        out[name] = (jnp.asarray(p), jnp.asarray(l))
    return out

def run():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MGMT_PORT)
    batches = _batches()
    fn = jax.jit(lambda s, p, l: stack.rx_tx(s, p, l))

    us = {}
    for name, (p, l) in batches.items():
        us[name] = time_call(fn, stack.init_state(), p, l, warmup=3,
                             iters=21)

    overhead = (us["mixed"] / us["pure"] - 1) * 100
    out = [row("mgmt_dataplane_pure", us["pure"] / BATCH,
               f"batch={BATCH} baseline"),
           row("mgmt_interleave_1pct", us["mixed"] / BATCH,
               f"overhead={overhead:+.1f}% (claim: control never "
               f"contends)"),
           row("mgmt_ack_batch", us["mgmt"] / BATCH,
               "management-only acks")]
    append_trajectory(OUT_PATH, {
        "batch": BATCH, "pure_us": us["pure"], "mixed_us": us["mixed"],
        "mgmt_only_us": us["mgmt"], "interleave_overhead_pct": overhead})
    return out


if __name__ == "__main__":
    run()
