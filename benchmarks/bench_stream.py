"""Streamed vs per-batch executor throughput (the streaming-executor
tentpole metric; first point in the perf trajectory).

Per-batch baseline = the pre-streaming harness shape, per batch: a fresh
``to_batch`` pack (numpy allocation), a host->device transfer, one jitted
``rx_tx`` dispatch, and a host sync on the result — what every benchmark
and the netem tick loop paid before `run_stream` existed.  Streamed = an
in-place `FrameArena` refill + ONE donated `run_stream` dispatch for the
whole window + one sync.

Appends a trajectory entry to ``BENCH_stream.json`` (history across PRs,
like BENCH_rpc_tail.json) and gates: streamed UDP echo CPU pps must be
>= 3x the per-batch baseline (`make bench-stream` fails otherwise)."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import append_trajectory, row
from repro.apps import echo
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_stream.json")


def measure(n_batches: int = 64, batch: int = 16, frame_payload: int = 64,
            repeats: int = 5):
    """Returns {per_batch_pps, streamed_pps, speedup, ...} for one config.
    Telemetry stays ON — this is the full production pipeline, counters
    included."""
    stack = UdpStack([echo.make(port=7)], IP_S)
    fr = F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                         rpc.np_frame(rpc.MSG_ECHO, 0,
                                      b"x" * frame_payload))
    frames = [fr] * batch
    width = len(fr) + 64
    arena = F.FrameArena(n_batches, batch, width)
    arena.fill(frames * n_batches)

    fn = jax.jit(stack.rx_tx, donate_argnums=(0,))
    stream = stack.stream_fn()
    n_pkts = n_batches * batch

    def per_batch(st):
        t0 = time.perf_counter()
        for _ in range(n_batches):
            p, l = F.to_batch(frames, width)       # fresh pack per batch
            st, q, ql, alive, info = fn(st, jnp.asarray(p),
                                        jnp.asarray(l))
            np.asarray(ql)                         # per-batch host sync
        return st, time.perf_counter() - t0

    def streamed(st):
        t0 = time.perf_counter()
        arena.fill(frames * n_batches)             # in-place refill
        st, outs = stream(st, jnp.asarray(arena.payload),
                          jnp.asarray(arena.length))
        jax.block_until_ready(outs)
        return st, time.perf_counter() - t0

    st_b, _ = per_batch(stack.init_state())        # compile + warm
    st_s, _ = streamed(stack.init_state())
    ts_b, ts_s = [], []
    for _ in range(repeats):
        st_b, t = per_batch(st_b)
        ts_b.append(t)
        st_s, t = streamed(st_s)
        ts_s.append(t)
    t_b, t_s = min(ts_b), min(ts_s)
    return {
        "n_batches": n_batches, "batch": batch,
        "frame_bytes": len(fr), "packets_per_window": n_pkts,
        "per_batch_us": t_b * 1e6, "streamed_us": t_s * 1e6,
        "per_batch_pps": n_pkts / t_b, "streamed_pps": n_pkts / t_s,
        "speedup": t_b / t_s,
    }


def run():
    r = measure()
    out = [row("stream_udp_echo_per_batch",
               r["per_batch_us"] / r["packets_per_window"],
               f"cpu={r['per_batch_pps']:.0f}pps"),
           row("stream_udp_echo_streamed",
               r["streamed_us"] / r["packets_per_window"],
               f"cpu={r['streamed_pps']:.0f}pps "
               f"speedup={r['speedup']:.2f}x")]
    append_trajectory(OUT_PATH, r)       # flat entry, same shape as the
    # other BENCH_*.json trajectories (older points nested it under
    # "udp_echo")
    if r["speedup"] < 3.0:
        raise RuntimeError(
            f"streamed UDP echo is only {r['speedup']:.2f}x the per-batch "
            f"baseline (gate: >= 3x)")
    return out


if __name__ == "__main__":
    run()
