"""Paper Figure 10: TCP connection live migration timeline.

A client sends a request every 100 us (simulated clock).  At t=0.07 s the
connection migrates: serialize on engine A, control-plane NAT rewrite,
reinstall on engine B.  Reported: simulated downtime (the paper measures
500 us), requests served before/after, and the serialize/install wall
cost."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import control
from repro.net import eth, frames as F, ipv4, nat, tcp

IP_C = F.ip("10.0.0.2")
VIP = F.ip("20.0.0.9")       # stable virtual IP the client talks to
IP_A = F.ip("10.0.0.1")      # engine A physical
IP_B = F.ip("10.0.0.7")      # engine B physical
PERIOD_US = 100


def _rx(conn, table, frame, n=1):
    payload, length = F.to_batch([frame], 256)
    p, l = jnp.asarray(payload), jnp.asarray(length)
    p, l, m = eth.parse(p, l)
    p, l, m2, ok = ipv4.parse(p, l)
    m.update(m2)
    m, _ = nat.rx(table, m)
    data, dlen, m = tcp.parse_segment(p, l, m)
    return tcp.rx_batch(conn, data, dlen, m)


def run():
    out = []
    table = nat.init([(VIP, IP_A)])
    conn_a = tcp.init(local_ip=IP_A)
    # handshake (client talks to the VIP throughout)
    conn_a, r = _rx(conn_a, table, F.tcp_eth_frame(IP_C, VIP, 4000, 80,
                                                   seq=100, ack=0,
                                                   flags=tcp.SYN))
    iss = int(r["tcp_seq"][0])
    conn_a, _ = _rx(conn_a, table, F.tcp_eth_frame(IP_C, VIP, 4000, 80,
                                                   seq=101, ack=iss + 1,
                                                   flags=tcp.ACK))
    # steady state: 1 request / 100us until migration at t = 0.07 s
    t_us, seq, served_a = 0, 101, 0
    while t_us < 70_000:
        frame = F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=seq, ack=iss + 1,
                                flags=tcp.ACK | tcp.PSH, payload=b"req!")
        if served_a < 3:     # run a few real packets; fast-forward the rest
            conn_a, resp = _rx(conn_a, table, frame)
            assert bool(resp["emit"][0])
        seq += 4
        served_a += 1
        t_us += PERIOD_US
    # catch the connection state up to the simulated stream position
    conn_a["rcv_nxt"] = conn_a["rcv_nxt"].at[0].set(jnp.uint32(seq))

    # ---- migration: serialize -> NAT rewrite -> reinstall -----------------
    def migrate():
        blob = tcp.serialize_conn(conn_a, 0)
        t2 = nat.update(table, 0, VIP, IP_B)
        conn_b = tcp.init(local_ip=IP_B)
        conn_b = tcp.install_conn(conn_b, 3, blob)
        return conn_b, t2

    us_mig = time_call(lambda: jax.block_until_ready(
        jax.tree.leaves(migrate()[0])[0]))
    conn_b, table = migrate()

    # ctrl-plane confirmation (paper: controller acks the external RPC)
    ctrl = control.make_controller()
    cmd = control.decode_command(jnp.asarray(
        [control.OP_NAT_SET, 0, 0, VIP, IP_B], jnp.uint32))
    ctrl, tables, ack = control.controller_apply(ctrl, cmd, {"nat": table})

    # connection continues on engine B without a reset
    frame = F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=seq, ack=iss + 1,
                            flags=tcp.ACK | tcp.PSH, payload=b"req!")
    conn_b, resp = _rx(conn_b, tables["nat"], frame)
    ok = bool(resp["emit"][0]) and int(resp["tcp_ack"][0]) == seq + 4

    # blob size determines the minimum downtime over the wire
    blob = tcp.serialize_conn(conn_a, 0)
    blob_bytes = sum(np.asarray(v).nbytes for v in jax.tree.leaves(blob))
    wire_us = blob_bytes * 8 / 100e9 * 1e6   # 100G link
    downtime = max(PERIOD_US, wire_us + 2 * 0.368 * 2)
    out.append(row("fig10_migration", us_mig,
                   f"survived={ok} downtime~{downtime:.0f}us(sim) "
                   f"blob={blob_bytes}B served_before={served_a} "
                   f"(paper: 500us)"))
    return out


if __name__ == "__main__":
    run()
