"""TCP goodput under loss (the paper's missing experiment: §4.4 ships
without congestion control, so the stack was never measured on a lossy
fabric).

Drives one server->client transfer through the deterministic netem link
at 0% / 0.1% / 1% i.i.d. loss with the NewReno engine and reports
goodput (payload bytes per emulated tick), the fraction of lossless
goodput retained, and the p99 / max recovery gap (ticks between
consecutive in-order advances at the client — the recovery-latency tail).

Gate (ISSUE 3 acceptance): at 1% loss the transfer must complete with
zero permanent stalls and sustain >= 20% of the lossless goodput.

Appends a trajectory entry to ``BENCH_tcp_loss.json`` (history across
PRs, like the other BENCH_*.json files)."""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import append_trajectory, row
from repro.net import frames as F
from repro.net.stack import TcpStack
from repro.netem import Link, LinkConfig, LinuxTcpClient, StackEndpoint, \
    run_transfer

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_tcp_loss.json")
MSS = 1024
PAYLOAD_BYTES = 32768
LOSS_RATES = (0.0, 0.001, 0.01)
MAX_TICKS = 20000


def _transfer(srv, loss, seed=11):
    srv.reset()
    client = LinuxTcpClient(IP_C, IP_S)
    l_cs = Link(LinkConfig(delay=2, seed=seed))
    l_sc = Link(LinkConfig(delay=2, loss=loss, seed=seed + 1))
    payload = bytes(np.random.default_rng(3).integers(
        0, 256, PAYLOAD_BYTES, dtype=np.uint8))
    t0 = time.perf_counter()
    stats = run_transfer(srv, client, l_cs, l_sc, payload,
                         max_ticks=MAX_TICKS)
    return stats, (time.perf_counter() - t0) * 1e6


def run():
    stack = TcpStack(IP_S, max_conns=4, cc_policy="newreno",
                     options={"tcp_tx_buf": PAYLOAD_BYTES + 4096,
                              "mss": MSS})
    srv = StackEndpoint(stack, mss=MSS, rx_width=96, burst=8)
    _transfer(srv, 0.0)                      # jit warmup

    out = []
    base = None
    traj = {"payload_bytes": PAYLOAD_BYTES, "mss": MSS}
    for loss in LOSS_RATES:
        stats, us = _transfer(srv, loss)
        if not stats.complete:
            raise RuntimeError(
                f"permanent stall at {loss:.1%} loss: {stats}")
        if base is None:
            base = stats.goodput
        rel = stats.goodput / base
        cc = srv.state["conn"]["cc"]
        retx = int(cc["retx_fast"][0]) + int(cc["retx_timer"][0])
        traj[f"loss_{loss:g}"] = {
            "us": us, "goodput_B_per_tick": stats.goodput, "rel": rel,
            "p99_gap": float(stats.p99_gap), "max_gap": int(stats.max_gap),
            "retx": retx}
        out.append(row(
            f"tcp_loss_{loss:g}", us,
            f"goodput={stats.goodput:.0f}B/tick rel={rel:.0%} "
            f"p99_gap={stats.p99_gap:.0f}t max_gap={stats.max_gap}t "
            f"retx={retx}"))
        if loss == 0.01 and rel < 0.20:
            raise RuntimeError(
                f"1% loss sustains only {rel:.0%} of lossless goodput "
                f"(gate: >= 20%)")
    append_trajectory(OUT_PATH, traj)

    # harness RX path: per-batch dispatch loop vs arena-streamed push
    # (stream=False forces the pre-streaming per-chunk Python loop; same
    # links/seeds/payload, but the streamed push services a whole burst
    # before emitting retransmits — recovery work under loss can differ
    # slightly, so read this as a harness-cost indicator, not a
    # controlled A/B of the engine)
    srv_b = StackEndpoint(stack, mss=MSS, rx_width=96, burst=8,
                          stream=False)
    _transfer(srv_b, 0.01)                   # jit warmup
    _, us_b = _transfer(srv_b, 0.01)
    _, us_s = _transfer(srv, 0.01)
    out.append(row("tcp_loss_harness_stream", us_s,
                   f"per_batch={us_b:.0f}us streamed={us_s:.0f}us "
                   f"speedup={us_b / max(us_s, 1):.2f}x"))
    return out


if __name__ == "__main__":
    run()
