"""Paper Figure 6: UDP echo goodput vs packet size.

Measured: CPU-backend batch throughput through the full jitted stack —
per-batch (one dispatch + host sync per batch) AND streamed (N batches
device-resident under one `run_stream` scan, state donated).  Derived:
TPU-projected goodput (Gbps) from compiled per-batch HBM traffic vs v5e
bandwidth, and the NoC-model chain latency (the paper's 368 ns figure
for a 1-byte echo).

The jit wrappers are hoisted out of the size loop (one `jax.jit` object,
cached per shape) and the state argument is donated — `time_call`'s
carry threading keeps the live state valid across iterations.

Reading the stream rows: with device-resident inputs the streamed win is
dispatch-bound, so it shows at small/medium frames; at jumbo sizes the
CPU backend turns cache-bandwidth-bound over the multi-batch arena and
the per-batch path's hot reused buffers win — the TPU projection (and
`make bench-stream`, whose baseline pays the real per-batch host work)
is the paper-relevant comparison there."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import (append_trajectory, hlo_traffic, row,
                               time_call)
from repro.apps import echo
from repro.core.noc import chain_latency_ns
from repro.launch.hlo_analysis import HBM_BW
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
BATCH = 64
STREAM_BATCHES = 16
SIZES = (64, 256, 1024, 4096, 8962)
OUT_PATH = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_udp_echo.json")


def run():
    traj = {}
    stack = UdpStack([echo.make(port=7, n_replicas=1)], IP_S,
                     with_telemetry=False)
    # ONE jit per entry point, hoisted out of the size loop: jax caches a
    # compiled executable per input shape, so each size traces once
    # instead of once per timing iteration
    fn = jax.jit(stack.rx_tx, donate_argnums=(0,))
    stream = stack.stream_fn()
    out = []
    for size in SIZES:
        pay = max(1, size - 42 - rpc.HLEN)   # eth+ip+udp+rpc overhead
        fr = F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                             rpc.np_frame(rpc.MSG_ECHO, 0, b"x" * pay))
        frames = [fr] * BATCH
        width = max(512, size + 64)
        payload, length = F.to_batch(frames, width)
        p, l = jnp.asarray(payload), jnp.asarray(length)

        state = stack.init_state()
        us = time_call(fn, state, p, l, carry=True)
        w = hlo_traffic(lambda s, pp, ll: stack.rx_tx(s, pp, ll),
                        stack.init_state(), p, l)
        per_pkt_bytes = w.hbm_bytes / BATCH
        proj_pps = HBM_BW / max(per_pkt_bytes, 1)
        proj_gbps = proj_pps * size * 8 / 1e9
        cpu_pps = BATCH / (us / 1e6)
        out.append(row(f"fig6_udp_echo_{size}B", us / BATCH,
                       f"proj={min(proj_gbps, 100.0):.1f}Gbps "
                       f"cpu={cpu_pps:.0f}pps"))

        # streamed: STREAM_BATCHES device-resident batches per dispatch
        arena = F.FrameArena(STREAM_BATCHES, BATCH, width)
        arena.fill(frames * STREAM_BATCHES)
        sp, sl = jnp.asarray(arena.payload), jnp.asarray(arena.length)
        us_s = time_call(stream, stack.init_state(), sp, sl, carry=True)
        n_pkts = STREAM_BATCHES * BATCH
        stream_pps = n_pkts / (us_s / 1e6)
        out.append(row(f"fig6_udp_echo_{size}B_stream", us_s / n_pkts,
                       f"cpu={stream_pps:.0f}pps "
                       f"speedup={stream_pps / cpu_pps:.2f}x"))
        traj[f"pps_{size}B"] = cpu_pps
        traj[f"stream_pps_{size}B"] = stream_pps
        traj[f"proj_gbps_{size}B"] = min(proj_gbps, 100.0)
    # paper's latency figure: eth->ip->udp->app->udp->ip->eth chain, 1 byte
    lat = chain_latency_ns([(0, 0), (1, 0), (2, 0), (3, 0), (2, 1), (1, 1),
                            (0, 1)], payload_bytes=1)
    out.append(row("fig6_udp_echo_latency", lat / 1000,
                   f"noc_chain={lat:.0f}ns (paper: 368ns)"))
    traj["noc_chain_latency_ns"] = lat
    append_trajectory(OUT_PATH, traj)
    return out


if __name__ == "__main__":
    run()
