"""End-to-end behaviour tests for the whole system: the paper's deployment
story in one test — packets from an unmodified client, through the
validated stack, into a replicated accelerator app, and back; plus the
TCP live-migration e2e and the dry-run machinery on a small mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo, reed_solomon
from repro.core import analyze
from repro.net import eth, frames as F, ipv4, nat, rpc, tcp, udp
from repro.net.stack import TcpStack, UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
VIP = F.ip("20.0.0.9")


def test_full_udp_deployment_roundtrip():
    """Fig. 1(b): direct-attached accelerator serving standard clients."""
    stack = UdpStack([echo.make(port=7, n_replicas=2),
                      reed_solomon.make(port=9000, n_replicas=4)], IP_S)
    assert analyze(stack.topo).ok
    state = stack.init_state()
    frames = [
        F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                        rpc.np_frame(rpc.MSG_ECHO, 1, b"hi")),
        F.udp_rpc_frame(IP_C, IP_S, 5001, 9000,
                        rpc.np_frame(rpc.MSG_RS_ENCODE, 2, bytes(4096))),
        F.udp_rpc_frame(IP_C, IP_S, 5002, 4444,          # unknown port
                        rpc.np_frame(rpc.MSG_ECHO, 3, b"drop-me")),
    ]
    payload, length = F.to_batch(frames, 4400)
    state, q, ql, alive, info = jax.jit(stack.rx_tx)(
        state, jnp.asarray(payload), jnp.asarray(length))
    assert bool(alive[0]) and bool(alive[1])
    # replies re-parse cleanly as valid frames (client interop both ways)
    p, l, m = eth.parse(q, ql)
    p, l, m2, ok_ip = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok_udp = udp.parse(p, l, m)
    assert bool(ok_ip[0]) and bool(ok_udp[0])
    assert int(m3["dst_port"][0]) == 5000      # reply routed to the client


def test_tcp_stack_with_nat_migration_e2e():
    """Fig. 10 end-to-end: client talks to a virtual IP; the connection
    migrates between two stacks; no reset, stream position preserved."""
    a = TcpStack(IP_S, with_nat=True, nat_entries=[(VIP, IP_S)])
    sa = a.init_state()

    def run(stack, st, frame):
        payload, length = F.to_batch([frame], 256)
        return stack.rx(st, jnp.asarray(payload), jnp.asarray(length))

    sa, r = run(a, sa, F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=900, ack=0,
                                       flags=tcp.SYN))
    iss = int(r["tcp_seq"][0])
    sa, _ = run(a, sa, F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=901,
                                       ack=iss + 1, flags=tcp.ACK))
    sa, _ = run(a, sa, F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=901,
                                       ack=iss + 1, flags=tcp.ACK | tcp.PSH,
                                       payload=b"before"))
    # migrate: serialize conn, retarget NAT (control plane), reinstall
    blob = tcp.serialize_conn(sa["conn"], 0)
    b = TcpStack(F.ip("10.0.0.7"), with_nat=True,
                 nat_entries=[(VIP, F.ip("10.0.0.7"))])
    sb = b.init_state()
    sb["conn"] = tcp.install_conn(sb["conn"], 0, blob)
    sb, r2 = run(b, sb, F.tcp_eth_frame(IP_C, VIP, 4000, 80, seq=907,
                                        ack=iss + 1,
                                        flags=tcp.ACK | tcp.PSH,
                                        payload=b"after"))
    assert int(r2["tcp_ack"][0]) == 912        # stream continues seamlessly
    conn, data, ok = tcp.app_read(sb["conn"], 0, 11)
    assert bool(ok) and bytes(data.tolist()) == b"beforeafter"


def test_dryrun_machinery_small_mesh():
    """The dry-run pipeline itself (lower + compile + walk + roofline) on
    the devices we actually have."""
    from repro.launch import hlo_walk
    from repro.launch.hlo_analysis import Roofline, model_flops_for
    from repro.configs import get_smoke_config
    from repro.configs.shapes import ShapeSpec
    from repro.models import model
    from repro.optim import adamw
    from repro.launch.steps import make_train_step
    from repro.sharding import SINGLE

    cfg = get_smoke_config("internlm2-1.8b")
    step = make_train_step(cfg, SINGLE)
    params = jax.eval_shape(lambda: model.init_params(cfg, jax.random.key(0)))
    opt = jax.eval_shape(lambda: adamw.init(
        model.init_params(cfg, jax.random.key(0))))
    batch = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
    compiled = jax.jit(step).lower(params, opt, batch).compile()
    w = hlo_walk.walk(compiled.as_text())
    assert w.flops > 0 and w.hbm_bytes > 0
    mf = model_flops_for(cfg, ShapeSpec("t", "train", 16, 2),
                         model.count_params(cfg), model.count_params(cfg))
    ro = Roofline(flops=w.flops, hbm_bytes=w.hbm_bytes, coll_bytes=0.0,
                  model_flops=mf)
    assert ro.bottleneck in ("compute", "memory")
    assert 0 < ro.useful_flop_fraction < 2.0
