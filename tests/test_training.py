"""Training substrate: loss goes down, checkpoint/restore bit-exact resume,
elastic resharding, async checkpointing, gradient compression, data
determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, Loader
from repro.models import model
from repro.optim import adamw, compress
from repro.train.trainer import TrainConfig, Trainer


def small_setup(tmpdir, total=30, arch="qwen1.5-0.5b"):
    cfg = get_smoke_config(arch)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    tcfg = TrainConfig(total_steps=total, ckpt_every=10, log_every=5,
                       ckpt_dir=str(tmpdir),
                       opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                             total_steps=total))
    return Trainer(cfg, tcfg, dcfg)


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    tr = small_setup(tmp_path)
    out = tr.run()
    log = out["log"]
    assert out["final_step"] == 30
    assert log[-1]["loss"] < log[0]["loss"] * 0.9


@pytest.mark.slow
def test_resume_is_bit_exact(tmp_path):
    tr1 = small_setup(tmp_path / "a")
    tr1.run(steps=20)
    tr1.save(sync=True)
    loss_ref = tr1.run(steps=5)["log"]

    tr2 = small_setup(tmp_path / "a")
    assert tr2.restore()
    assert tr2.step == 20
    loss_resumed = tr2.run(steps=5)["log"]
    assert loss_resumed[-1]["loss"] == pytest.approx(
        loss_ref[-1]["loss"], abs=0)


def test_elastic_restore_changes_layout(tmp_path):
    tr = small_setup(tmp_path)
    tr.run(steps=5)
    tr.save(sync=True)
    # restore with explicit shardings (single device -> same values)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.compat import make_mesh
    mesh = make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tr.state_tree())
    state = ckpt.restore(tr.tcfg.ckpt_dir, tr.state_tree(), shardings=sh)
    chk = jax.tree.leaves(state["params"])[0]
    assert chk.sharding == NamedSharding(mesh, P())


def test_async_checkpointer_commits(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    c.save(3, tree)
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    back = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(5))


def test_data_determinism_and_sharding():
    dcfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    a = Loader(dcfg).batch(3)
    b = Loader(dcfg).batch(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # rank slicing partitions the global batch
    h0 = Loader(dcfg, rank=0, size=2).batch(3)
    h1 = Loader(dcfg, rank=1, size=2).batch(3)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), a["tokens"])


def test_grad_compression_error_feedback_converges():
    # ef-compressed mean over "pods" tracks the true mean over repeated steps
    key = jax.random.key(0)
    g = jax.random.normal(key, (256,))
    r = jnp.zeros((256,))
    applied = jnp.zeros((256,))
    for _ in range(8):
        q, scale, r = compress.ef_compress(g, r)
        applied += compress.dequantize(q, scale)
    # telescoping: sum of applied ~= 8 * g with bounded residual
    err = jnp.abs(applied - 8 * g).max() / jnp.abs(g).max()
    assert float(err) < 0.05


@pytest.mark.slow
def test_preemption_checkpoint(tmp_path):
    tr = small_setup(tmp_path)
    tr.run(steps=7)
    tr._stop = True
    tr.run(steps=100)          # stops immediately, grace-checkpoints
    assert ckpt.latest_step(str(tmp_path)) == 7
