"""Cross-device sharding of the compiled pipeline (net.shard): per-shard
bit-identity, no cross-shard collectives, per-shard console addressing,
prom shard labels.  Needs >1 device, so the suite runs on the shared
forced-host-mesh fixture."""
import pytest

pytestmark = pytest.mark.slow

_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp

from repro.apps import echo
from repro.net import frames as F, rpc
from repro.net.shard import ShardedConsole, ShardedStream
from repro.net.stack import UdpStack, replicated_udp_topology

S = 4
IP_S = F.ip("10.0.0.1")


def make_stack():
    apps = [echo.make(port=7)]
    topo = replicated_udp_topology(apps, n_rx=2, policy="flow_hash")
    return UdpStack(apps, IP_S, topo=topo, mgmt_port=9909)


stack = make_stack()
ss = ShardedStream(stack, shards=S)
arena = ss.make_arena(n_batches=2, batch=16, max_len=256)
flows = {p: [F.udp_rpc_frame(F.ip("10.0.0.9"), IP_S, p, 7,
                             rpc.np_frame(rpc.MSG_ECHO, i, b"x" * 32))
             for i in range(4)]
         for p in range(5000, 5032)}
counts = arena.fill_rss(flows)
assert all(c == 32 for c in counts), counts

# --- sharded egress is bit-identical to per-partition references ----------
state = ss.init_state()
state, outs = ss.run_stream(state, arena.payload, arena.length)
outs_np = jax.tree.map(np.asarray, outs)
for s in range(S):
    ref = make_stack()
    rst, r = ref.run_stream(ref.init_state(),
                            jnp.asarray(arena.payload[s]),
                            jnp.asarray(arena.length[s]))
    assert np.array_equal(np.asarray(r["tx_payload"]),
                          outs_np["tx_payload"][s]), s
    assert np.array_equal(np.asarray(r["alive"]), outs_np["alive"][s]), s
assert int(outs_np["alive"].sum()) == S * 32
print("SHARD_BITIDENT_OK")

# --- no cross-shard collectives in the lowered program --------------------
hlo = jax.jit(ss._sharded).lower(
    ss.init_state(), jnp.asarray(arena.payload),
    jnp.asarray(arena.length)).compile().as_text()
banned = [b for b in ("all-reduce", "all-gather", "collective-permute",
                      "all-to-all") if b in hlo]
assert not banned, banned
print("SHARD_NOCOLL_OK")

# --- per-shard console addressing -----------------------------------------
con = ShardedConsole(stack, S)
# per-shard LOG_READ: every shard served its 32 frames through udp_rx
for s in range(S):
    state, r = con.read_counters(state, s, "udp_rx")
    assert r["status"] == 1, (s, r)
    assert r["row"]["packets_in"] > 0, (s, r)
# shard-local GROUP_READ + drain: shard 1 drains lane 0, siblings keep it
state, r = con.drain_replica(state, 1, "udp_rx", 0)
assert r["status"] == 1
state, r1 = con.read_group(state, 1, "udp_rx")
assert r1["group"]["healthy"] == [False, True], r1
for s in (0, 2, 3):
    state, rs = con.read_group(state, s, "udp_rx")
    assert rs["group"]["healthy"] == [True, True], (s, rs)
# drained shard still serves ALL its frames on the surviving lane
state, outs = ss.run_stream(state, arena.payload, arena.length)
alive = np.asarray(outs["alive"])
assert int(alive[1].sum()) == 32
lanes = np.asarray(outs["info"]["udp_rx.lane"])[1]
assert set(np.unique(lanes[lanes >= 0])) == {1}
# per-shard DROP_READ answers from that shard's tables
state, rd = con.read_drops(state, 0, "eth_rx")
assert rd["status"] > 0
state, dump = con.dump_counters(state)
assert sorted(dump) == list(range(S)) and all(dump.values())
print("SHARD_CONSOLE_OK")

# --- prom exposition carries the shard label ------------------------------
from repro.obs import prom
state, outs = ss.run_stream(state, arena.payload, arena.length)
text = prom.render_sharded(state, stack.pipeline)
assert 'shard="0"' in text and 'shard="%d"' % (S - 1) in text
assert text.count("# HELP beehive_window_frames") == 1   # headers deduped
print("SHARD_PROM_OK")
"""


@pytest.mark.parametrize("marker", ["SHARD_BITIDENT_OK", "SHARD_NOCOLL_OK",
                                    "SHARD_CONSOLE_OK", "SHARD_PROM_OK"])
def test_sharded_dataplane_suite(marker, sharded_output):
    assert marker in sharded_output


@pytest.fixture(scope="module")
def sharded_output(forced_host_mesh):
    return forced_host_mesh(_SCRIPT, devices=4)
