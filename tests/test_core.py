"""Beehive core: topology validation, deadlock analysis (paper Fig. 5),
routing tables, scale-out dispatch, control plane."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (DROP, DeadlockReport, RouteTable, TopologyConfig,
                        analyze, flow_hash, make_table)
from repro.core import control, scaleout
from repro.core.noc import chain_channels, chain_latency_ns, dor_path


# ---------------------------------------------------------------------------
# NoC model


def test_dor_path_x_then_y():
    path = dor_path((0, 0), (2, 1))
    assert [(c.src, c.dst) for c in path] == [
        ((0, 0), (1, 0)), ((1, 0), (2, 0)), ((2, 0), (2, 1))]


def test_chain_latency_matches_paper_magnitude():
    # paper: 368 ns (92 cycles) through eth->ip->udp->app->udp->ip->eth
    chain = [(0, 0), (1, 0), (2, 0), (3, 0), (2, 0), (1, 0), (0, 0)]
    ns = chain_latency_ns(chain, payload_bytes=64)
    assert 200 < ns < 600


# ---------------------------------------------------------------------------
# deadlock (paper Fig. 5)


def _fig5(layout):
    topo = TopologyConfig("fig5", 4, 1)
    for name, (x, y) in layout.items():
        topo.add_tile(name, name, x, y)
    topo.add_chain("eth_rx", "ip_rx", "udp_rx", "app")
    return topo


def test_fig5a_deadlocks():
    # IP placed past UDP: udp->app must re-acquire the (1,0)->(2,0) link
    topo = _fig5({"eth_rx": (0, 0), "udp_rx": (1, 0),
                  "ip_rx": (2, 0), "app": (3, 0)})
    rep = analyze(topo)
    assert not rep.ok
    assert rep.self_conflicts or rep.cycles


def test_fig5b_safe():
    topo = _fig5({"eth_rx": (0, 0), "ip_rx": (1, 0),
                  "udp_rx": (2, 0), "app": (3, 0)})
    rep = analyze(topo)
    assert rep.ok, rep.summary()


def test_cross_chain_cycle_detected():
    topo = TopologyConfig("cross", 2, 2)
    topo.add_tile("a", "a", 0, 0)
    topo.add_tile("b", "b", 1, 0)
    topo.add_tile("c", "c", 1, 1)
    topo.add_tile("d", "d", 0, 1)
    # two chains that wait on each other's channels around the ring
    topo.add_chain("a", "b", "c")
    topo.add_chain("c", "d", "a")
    rep = analyze(topo)
    # DOR makes this particular pair safe or not; the analysis must at
    # least run and produce a coherent verdict
    assert isinstance(rep, DeadlockReport)


def test_ipinip_duplicated_tiles_avoid_reacquisition():
    # repeated IP headers break resource ordering unless the tile is
    # duplicated (paper: two IP RX tiles)
    topo = TopologyConfig("ipinip-bad", 4, 1)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    topo.add_tile("app", "app", 2, 0)
    topo.add_chain("eth_rx", "ip_rx", "ip_rx", "app")  # decap loops back
    rep = analyze(topo)
    assert rep.ok  # same-tile hop uses no channels; now the deadlock case:
    topo2 = TopologyConfig("ipinip-loop", 4, 1)
    topo2.add_tile("eth_rx", "eth_rx", 0, 0)
    topo2.add_tile("ip_rx", "ip_rx", 2, 0)
    topo2.add_tile("decap", "ipinip", 1, 0)
    topo2.add_tile("app", "app", 3, 0)
    # ip -> decap (west) -> ip again (east, re-acquiring (1,0)->(2,0))
    topo2.add_chain("eth_rx", "ip_rx", "decap", "ip_rx", "app")
    rep2 = analyze(topo2)
    assert not rep2.ok
    # the fix: duplicate the IP tile after decap
    topo3 = TopologyConfig("ipinip-dup", 4, 1)
    topo3.add_tile("eth_rx", "eth_rx", 0, 0)
    topo3.add_tile("ip_rx", "ip_rx", 1, 0)
    topo3.add_tile("ip_rx2", "ip_rx", 2, 0)
    topo3.add_tile("app", "app", 3, 0)
    topo3.add_chain("eth_rx", "ip_rx", "ip_rx2", "app")
    assert analyze(topo3).ok


# ---------------------------------------------------------------------------
# topology validation + tooling


def test_validation_catches_errors():
    topo = TopologyConfig("bad", 2, 2)
    topo.add_tile("a", "a", 0, 0)
    topo.add_tile("a", "a", 5, 0)          # dup name + out of bounds
    topo.add_tile("b", "b", 0, 0)          # coordinate collision
    topo.add_chain("a", "missing")
    errs = topo.validate()
    assert len(errs) >= 3


def test_autofill_and_wiring():
    topo = TopologyConfig("t", 2, 2)
    topo.add_tile("a", "a", 0, 0)
    assert len(topo.filled_coords()) == 3      # empty router tiles
    assert len(topo.wiring()) == 4             # 2x2 mesh edges


def test_config_loc_counting():
    topo = TopologyConfig("t", 4, 4)
    topo.add_tile("udp_rx", "udp_rx", 0, 0)
    t = topo.add_tile("rs", "app:rs", 1, 0)
    topo.add_route("udp_rx", "udp_port", 9000, "rs")
    loc = topo.config_loc(["rs"])
    assert 0 < loc < 40       # paper Table 1: tens of lines per tile


# ---------------------------------------------------------------------------
# routing tables


def test_route_table_lookup_and_rewrite():
    t = make_table([(0x0800, 3), (0x86DD, 4)], default=DROP)
    field = jnp.asarray([0x0800, 0x1234, 0x86DD], jnp.int32)
    nxt = t.lookup(field)
    assert nxt.tolist() == [3, DROP, 4]
    t2 = t.set_entry(2, 0x1234, 7)        # runtime rewrite, no rebuild
    assert t2.lookup(field).tolist() == [3, 7, 4]


def test_flow_hash_is_flow_affine():
    meta = {k: jnp.asarray([1, 1, 2], jnp.int32)
            for k in ("src_ip", "dst_ip", "src_port", "dst_port")}
    h = flow_hash(meta)
    assert h[0] == h[1] and h[0] != h[2]


# ---------------------------------------------------------------------------
# scale-out dispatch


def test_round_robin_spreads_evenly():
    d = scaleout.make_dispatch([10, 11, 12, 13])
    mask = jnp.ones((8,), bool)
    d, nxt = scaleout.round_robin(d, mask)
    counts = [(nxt == t).sum() for t in (10, 11, 12, 13)]
    assert all(c == 2 for c in counts)
    assert int(d.rr_counter) == 8


def test_dispatch_skips_unhealthy():
    d = scaleout.make_dispatch([10, 11, 12, 13])
    d = scaleout.mark_health(d, 2, False)
    mask = jnp.ones((9,), bool)
    _, nxt = scaleout.round_robin(d, mask)
    assert 12 not in set(nxt.tolist())
    assert set(nxt.tolist()) == {10, 11, 13}


def test_port_match_shards():
    d = scaleout.make_dispatch([20, 21, 22, 23])
    port = jnp.asarray([9000, 9001, 9003], jnp.int32)
    nxt = scaleout.by_port(d, port, 9000)
    assert nxt.tolist() == [20, 21, 23]


def test_replicate_expands_chains():
    topo = TopologyConfig("t", 8, 2)
    topo.add_tile("udp_rx", "udp_rx", 0, 0)
    topo.add_tile("rs", "app:rs", 1, 0)
    topo.add_chain("udp_rx", "rs")
    names = scaleout.replicate(topo, "rs", 4,
                               [(1, 0), (2, 0), (3, 0), (4, 0)])
    assert len(names) == 4
    assert len(topo.chains) == 4
    assert not topo.has_tile("rs")
    assert analyze(topo).ok


# ---------------------------------------------------------------------------
# control plane


def test_controller_nat_update_versioned():
    ctrl = control.make_controller()
    tables = {"nat": {"virt": jnp.zeros((8,), jnp.uint32),
                      "phys": jnp.zeros((8,), jnp.uint32)}}
    cmd = control.decode_command(jnp.asarray(
        [control.OP_NAT_SET, 0, 3, 0x0A000001, 0x0A000002], jnp.uint32))
    ctrl, tables, ack = control.controller_apply(ctrl, cmd, tables)
    assert int(ctrl.version) == 1
    assert int(tables["nat"]["virt"][3]) == 0x0A000001
    assert int(tables["nat"]["phys"][3]) == 0x0A000002


def test_controller_health_update():
    ctrl = control.make_controller()
    tables = {"dispatch": scaleout.make_dispatch([1, 2, 3])}
    cmd = control.decode_command(jnp.asarray(
        [control.OP_HEALTH_SET, 0, 1, 0, 0], jnp.uint32))
    ctrl, tables, _ = control.controller_apply(ctrl, cmd, tables)
    assert not bool(tables["dispatch"].healthy[1])
    assert int(ctrl.version) == 1
