"""Device-resident observability layer (flight recorder, drop-reason
attribution, latency histograms, Perfetto export).

Acceptance coverage:
  * a runt UDP frame is attributed as exactly ONE `runt_udp` drop at the
    udp_rx tile (and distinct drop sites report distinct codes);
  * LOG_READ staleness window: a readback issued in batch k serves batch
    k-1's counters, under both `run` and `run_stream`;
  * recorder + histograms add zero host callbacks to the scanned region
    (jaxpr + HLO), and carrier outputs with tracing disabled are
    bit-identical to a `with_telemetry=False` stack;
  * TRACE_SET changes the live sampling rate with NO retrace;
  * the exporter writes valid Chrome trace-event JSON.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import echo
from repro.core import control
from repro.mgmt.console import MgmtConsole, command_frame, parse_response
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, rpc_serve_topology
from repro.obs import export, flight, reasons

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
MGMT = 9909


def echo_frame(sport, req=1, port=7, payload=b"x"):
    return F.udp_rpc_frame(IP_C, IP_S, sport, port,
                           rpc.np_frame(rpc.MSG_ECHO, req, payload))


def runt_frame(sport=7001):
    """A UDP frame whose udp_len field claims fewer than 8 header bytes."""
    fr = bytearray(echo_frame(sport))
    off = F.l2_offset(bytes(fr)) + 20 + 4       # IP header, then udp_len
    fr[off:off + 2] = (4).to_bytes(2, "big")
    return bytes(fr)


def ip_corrupt_frame(sport=7002):
    fr = bytearray(echo_frame(sport))
    fr[F.l2_offset(bytes(fr)) + 10] ^= 0xFF     # IP header checksum
    return bytes(fr)


def make_stack(**kw):
    return UdpStack([echo.make(port=7)], IP_S, **kw)


def batch_of(frames, width=256):
    p, l = F.to_batch(frames, width)
    return jnp.asarray(p), jnp.asarray(l)


def node(stack, name):
    return stack.pipeline.order.index(name)


# ---------------------------------------------------------------------------
# drop-reason attribution (satellite: distinct code per drop site)


def test_runt_udp_is_exactly_one_runt_drop():
    stack = make_stack()
    st = stack.init_state()
    p, l = batch_of([echo_frame(5000), runt_frame(), echo_frame(5001)])
    st, *_ = stack.rx_tx(st, p, l)
    drops = np.asarray(st["telemetry"]["drops"])
    # exactly one RUNT_UDP, at udp_rx, and nowhere else in the table
    assert drops[node(stack, "udp_rx"), reasons.RUNT_UDP] == 1
    assert drops[:, reasons.RUNT_UDP].sum() == 1
    assert drops.sum() == 1


def test_distinct_sites_report_distinct_codes():
    stack = make_stack()
    st = stack.init_state()
    p, l = batch_of([echo_frame(5000), runt_frame(), ip_corrupt_frame()])
    st, *_ = stack.rx_tx(st, p, l)
    drops = np.asarray(st["telemetry"]["drops"])
    assert drops[node(stack, "ip_rx"), reasons.IP_CSUM] == 1
    assert drops[node(stack, "udp_rx"), reasons.RUNT_UDP] == 1
    assert drops.sum() == 2


def test_drop_read_over_mgmt_plane():
    stack = make_stack(mgmt_port=MGMT)
    con = MgmtConsole(stack)
    st = stack.init_state()
    p, l = batch_of([runt_frame(), echo_frame(5000)])
    st, *_ = stack.rx_tx(st, p, l)
    st, r = con.read_drops(st, "udp_rx")
    assert r["reasons"] == {"runt_udp": 1}


# ---------------------------------------------------------------------------
# LOG_READ staleness window (satellite): batch k serves batch k-1's row


def _log_read_frame(req_id=1):
    return command_frame(IP_C, IP_S, 5999, MGMT, control.OP_LOG_READ,
                         a=0, b=0, req_id=req_id)   # eth_rx, age 0


def test_log_read_staleness_window_run():
    stack = make_stack(mgmt_port=MGMT)
    st = stack.init_state()
    traffic = [echo_frame(5000 + i) for i in range(4)]
    st, *_ = stack.rx_tx(st, *batch_of(traffic))                 # batch 1
    st, q, ql, alive, info = stack.rx_tx(st, *batch_of([_log_read_frame()]))
    r = parse_response(bytes(np.asarray(q)[0][: int(ql[0])].tobytes()))
    assert r["status"] == 1
    # served row is batch 1's (step 1, 4 arrivals at eth_rx) even though
    # the read itself executed inside batch 2
    assert r["row"]["step"] == 1
    assert r["row"]["packets_in"] == len(traffic)


def test_log_read_staleness_window_run_stream():
    stack = make_stack(mgmt_port=MGMT)
    st = stack.init_state()
    traffic = [echo_frame(5000 + i) for i in range(4)]
    arena = F.FrameArena(2, 4, 256)
    arena.fill(traffic + [_log_read_frame()])
    st, outs = stack.run_stream(st, jnp.asarray(arena.payload),
                                jnp.asarray(arena.length))
    q = np.asarray(outs["tx_payload"])[1, 0]
    ql = int(np.asarray(outs["tx_len"])[1, 0])
    r = parse_response(bytes(q[:ql].tobytes()))
    assert r["status"] == 1
    assert r["row"]["step"] == 1
    assert r["row"]["packets_in"] == len(traffic)


# ---------------------------------------------------------------------------
# zero host callbacks + bit-identity (satellite)


def _enable(st, shift=0):
    st = dict(st)
    st["telemetry"] = dict(st["telemetry"])
    obs = dict(st["telemetry"]["obs"])
    obs["ctrl"] = {"enable": jnp.ones((), jnp.int32),
                   "shift": jnp.full((), shift, jnp.int32)}
    st["telemetry"]["obs"] = obs
    return st


def test_recorder_and_histos_add_no_host_callbacks():
    stack = make_stack()
    st = _enable(stack.init_state())
    arena = F.FrameArena(2, 2, 256)
    arena.fill([echo_frame(5000 + i) for i in range(4)])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    fn = lambda s, pp, ll: stack.run_stream(s, pp, ll)
    closed = jax.make_jaxpr(fn)(st, p, l)
    prims = set()

    def walk(jaxpr):
        for eq in jaxpr.eqns:
            prims.add(eq.primitive.name)
            for v in eq.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax.core.ClosedJaxpr):
                        walk(s.jaxpr)
                    elif isinstance(s, jax.core.Jaxpr):
                        walk(s)

    walk(closed.jaxpr)
    assert "scan" in prims
    assert not prims & {"pure_callback", "io_callback", "debug_callback",
                        "infeed", "outfeed", "device_put"}

    hlo = jax.jit(fn).lower(st, p, l).compile().as_text().lower()
    assert "infeed" not in hlo and "outfeed" not in hlo
    assert "send-to-host" not in hlo and "recv-from-host" not in hlo


def test_tracing_disabled_outputs_bit_identical_to_no_telemetry():
    """With the recorder disabled (the init default) the carrier outputs
    must match a stack with no telemetry at all, bit for bit — and a
    with_obs=False stack likewise: observability never perturbs data."""
    arena = F.FrameArena(2, 3, 256)
    arena.fill([echo_frame(5000 + i) for i in range(5)] + [runt_frame()])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    outs = {}
    for key, kw in (("obs", {}), ("noobs", {"with_obs": False}),
                    ("notelem", {"with_telemetry": False})):
        stack = make_stack(**kw)
        _, o = stack.run_stream(stack.init_state(), p, l)
        outs[key] = o
    for k in ("tx_payload", "tx_len", "alive"):
        np.testing.assert_array_equal(np.asarray(outs["obs"][k]),
                                      np.asarray(outs["notelem"][k]),
                                      err_msg=k)
        np.testing.assert_array_equal(np.asarray(outs["obs"][k]),
                                      np.asarray(outs["noobs"][k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# live TRACE_SET: sampling knobs are runtime state, no retrace


def test_trace_set_live_without_retrace():
    stack = make_stack(mgmt_port=MGMT)
    traces = []

    def counted(st, p, l):
        traces.append(1)
        return stack.run_stream(st, p, l)

    fn = jax.jit(counted)
    width, batch = 256, 2

    def window(frames):
        arena = F.FrameArena(1, batch, width)
        arena.fill(frames)
        return jnp.asarray(arena.payload), jnp.asarray(arena.length)

    st = stack.init_state()
    st, _ = fn(st, *window([echo_frame(5000), echo_frame(5001)]))
    assert int(st["telemetry"]["obs"]["trace"].wr) == 0   # recorder off

    enable = command_frame(IP_C, IP_S, 5999, MGMT, control.OP_TRACE_SET,
                           a=1, b=0, req_id=7)            # record 1-in-1
    st, _ = fn(st, *window([enable, echo_frame(5002)]))
    st, _ = fn(st, *window([echo_frame(5003), echo_frame(5004)]))
    assert int(st["telemetry"]["obs"]["trace"].wr) == batch
    assert len(traces) == 1            # one compiled program served all


# ---------------------------------------------------------------------------
# flight-recorder contents + histograms + export


def test_flight_rows_record_visits_and_reasons():
    stack = make_stack()
    st = _enable(stack.init_state())
    p, l = batch_of([echo_frame(5000), runt_frame()])
    st, *_ = stack.rx_tx(st, p, l)
    rows = export.trace_rows(st["telemetry"]["obs"])
    assert [r["frame_id"] for r in rows] == [0, 1]
    good, runt = rows
    assert good["drop_reason"] == reasons.NONE
    assert node(stack, "eth_tx") in good["visited"]       # full traversal
    assert runt["drop_reason"] == reasons.RUNT_UDP
    assert node(stack, "udp_rx") in runt["visited"]
    assert node(stack, "eth_tx") not in runt["visited"]   # died at udp_rx
    for r in rows:
        for i in r["visited"]:
            assert r["exit"][i] > r["enter"][i]


def test_histograms_count_every_frame_when_enabled():
    stack = make_stack(mgmt_port=MGMT)
    con = MgmtConsole(stack)
    st = _enable(stack.init_state())
    n = 6
    st, *_ = stack.rx_tx(st, *batch_of([echo_frame(5000 + i)
                                        for i in range(n)]))
    histo = np.asarray(st["telemetry"]["obs"]["histo"])
    assert histo[node(stack, "eth_rx")].sum() == n        # per-stage row
    assert histo[-1].sum() == n                           # end-to-end row
    st, r = con.read_histo(st)                            # e2e over mgmt
    assert sum(r["table_row"]) >= n
    assert flight.percentile(r["table_row"], 0.5) >= 1


def test_perfetto_export_is_valid_trace_event_json(tmp_path):
    stack = make_stack()
    st = _enable(stack.init_state())
    st, *_ = stack.rx_tx(st, *batch_of([echo_frame(5000), runt_frame()]))
    path = str(tmp_path / "pipe.perfetto.json")
    n = export.write_perfetto(path, st, stack.pipeline)
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert len(events) == n and n > 2
    slices = [e for e in events if e["ph"] == "X"]
    assert slices, "no complete slices exported"
    names = {e["name"] for e in slices}
    assert "eth_rx" in names and "udp_rx" in names
    for e in slices:
        assert isinstance(e["ts"], int) and isinstance(e["dur"], int)
        assert e["dur"] > 0 and {"pid", "tid"} <= set(e)


def test_perfetto_export_captures_rpc_serve_path(tmp_path):
    """Acceptance: a captured RPC-serve trace — the rs_serve tile shows
    up as a slice in the exported trace, and an app-rejected request is
    attributed to it."""
    stack = UdpStack([], IP_S, topo=rpc_serve_topology(
        [("rs", "rs_serve", rpc.MSG_RS_ENCODE)]))
    st = _enable(stack.init_state())
    rng = np.random.default_rng(0)
    good = F.udp_rpc_frame(IP_C, IP_S, 5000, 9400,
                           rpc.np_frame(rpc.MSG_RS_ENCODE, 0,
                                        rng.bytes(4096)))
    bad = F.udp_rpc_frame(IP_C, IP_S, 5001, 9400,
                          rpc.np_frame(rpc.MSG_RS_ENCODE, 1, b"short"))
    st, *_ = stack.rx_tx(st, *batch_of([good, bad], width=4400))
    drops = np.asarray(st["telemetry"]["drops"])
    assert drops[node(stack, "rs"), reasons.APP_BAD_REQ] == 1
    path = str(tmp_path / "serve.perfetto.json")
    export.write_perfetto(path, st, stack.pipeline)
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    assert "rs" in {e["name"] for e in events if e["ph"] == "X"}
