"""Shared fixtures.

`forced_host_mesh` is the one way tests get a multi-device mesh on a CPU
box: XLA only honours ``--xla_force_host_platform_device_count`` before
the first jax import, so the snippet runs in a subprocess with a
prepared environment (repro.launch.hostmesh).  When the platform refuses
the forcing — an accelerator already claimed the process — the run is
*skipped* with a clear message instead of failing, so the suite stays
green on every backend.
"""
import os

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="session")
def forced_host_mesh():
    """Callable fixture: ``forced_host_mesh(script, devices=8)`` runs the
    python snippet on a host-simulated mesh and returns its stdout.
    Asserts a zero exit (stderr tail in the failure message); skips when
    the device forcing did not take."""
    from repro.launch import hostmesh

    def run(script: str, devices: int = 8, timeout: int = 900) -> str:
        out = hostmesh.run_script(script, devices=devices,
                                  timeout=timeout, cwd=_REPO)
        if hostmesh.UNAVAILABLE in out.stdout:
            pytest.skip(f"platform will not simulate {devices} host "
                        f"devices (got: {out.stdout.strip()})")
        assert out.returncode == 0, out.stderr[-3000:]
        return out.stdout

    return run
