"""End-to-end application tests: Figure-4 UDP stack with echo / RS / VR
apps, TCP live migration, LM serving engine + session migration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo, reed_solomon, vr_witness
from repro.apps.lm_server import (LmServerApp, decode_reply, encode_request)
from repro.configs import get_smoke_config
from repro.kernels.rs_encode import gf
from repro.kernels.rs_encode.ref import rs_encode_np
from repro.models import model
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack
from repro.serve.engine import ServeEngine

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")


def run_stack(stack, state, reqs, max_len=600):
    frames = [F.udp_rpc_frame(IP_C, IP_S, 5000 + i, port,
                              rpc.np_frame(mt, i, body))
              for i, (port, mt, body) in enumerate(reqs)]
    payload, length = F.to_batch(frames, max_len)
    return stack.rx_tx(state, jnp.asarray(payload), jnp.asarray(length))


def parse_reply(q, ql, i):
    from repro.net import eth, ipv4, udp
    p, l, m = eth.parse(q, ql)
    p, l, m2, ok1 = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok2 = udp.parse(p, l, m)
    body, blen, rmeta, ok3 = rpc.parse(p, l)
    assert bool(ok1[i]) and bool(ok2[i]) and bool(ok3[i])
    return bytes(np.asarray(body[i, :blen[i]]).tobytes()), m3


# ---------------------------------------------------------------------------


def test_udp_echo_through_stack():
    stack = UdpStack([echo.make(port=7, n_replicas=2)], IP_S)
    state = stack.init_state()
    state, q, ql, alive, info = run_stack(
        stack, state, [(7, rpc.MSG_ECHO, b"ping-0"), (7, rpc.MSG_ECHO, b"ping-1")])
    assert bool(alive.all())
    body, _ = parse_reply(q, ql, 0)
    assert body == b"ping-0"
    served = np.asarray(state["apps"]["echo"]["served"])
    assert served.sum() == 2 and (served == 1).all()  # round-robin spread


def test_rs_app_parity_correct():
    stack = UdpStack([reed_solomon.make(port=9000, n_replicas=4)], IP_S)
    state = stack.init_state()
    rng = np.random.default_rng(0)
    block = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    state, q, ql, alive, _ = run_stack(
        stack, state, [(9000, rpc.MSG_RS_ENCODE, block)], max_len=4400)
    body, _ = parse_reply(q, ql, 0)
    assert len(body) == 1024
    # oracle: parity over the 8x512 layout used by encode_blocks
    data = np.frombuffer(block, np.uint8).reshape(8, 512)
    want = rs_encode_np(data, gf.generator_matrix(8, 2)).reshape(-1)
    np.testing.assert_array_equal(np.frombuffer(body, np.uint8), want)


def test_rs_replicas_round_robin_scaleout():
    stack = UdpStack([reed_solomon.make(port=9000, n_replicas=4)], IP_S)
    state = stack.init_state()
    block = bytes(4096)
    reqs = [(9000, rpc.MSG_RS_ENCODE, block)] * 8
    state, *_ = run_stack(stack, state, reqs, max_len=4400)
    ops = np.asarray(state["apps"]["rs"]["ops"])
    assert (ops == 2).all()          # 8 requests over 4 replicas


def _vr_req(op, view, op_num, digest=0xABCD):
    import struct
    return struct.pack("!IIII", op, view, op_num, digest)


def test_vr_witness_prepare_and_read():
    stack = UdpStack([vr_witness.make(base_port=9100, n_shards=4)], IP_S)
    state = stack.init_state()
    reqs = [
        (9100, rpc.MSG_VR_PREPARE, _vr_req(vr_witness.OP_PREPARE, 0, 1)),
        (9100, rpc.MSG_VR_PREPARE, _vr_req(vr_witness.OP_PREPARE, 0, 2)),
        (9101, rpc.MSG_VR_PREPARE, _vr_req(vr_witness.OP_PREPARE, 0, 1)),
        (9100, rpc.MSG_VR_PREPARE, _vr_req(vr_witness.OP_READ_VERIFY, 0, 0)),
        (9100, rpc.MSG_VR_PREPARE, _vr_req(vr_witness.OP_PREPARE, 0, 9)),
    ]
    state, q, ql, alive, _ = run_stack(stack, state, reqs)
    vr = state["apps"]["vr"]
    assert int(vr["last_op"][0]) == 2          # shard 0: ops 1,2 in order
    assert int(vr["last_op"][1]) == 1          # shard 1 independent
    body, _ = parse_reply(q, ql, 3)
    assert body[:4] == b"\x00\x00\x00\x00"     # read verified (ST_OK)
    body4, _ = parse_reply(q, ql, 4)
    assert body4[:4] == b"\x00\x00\x00\x01"    # gap (op 9) rejected


def test_vr_view_change():
    stack = UdpStack([vr_witness.make(base_port=9100, n_shards=1)], IP_S)
    state = stack.init_state()
    reqs = [(9100, rpc.MSG_VR_PREPARE,
             _vr_req(vr_witness.OP_START_VIEW, 3, 0)),
            (9100, rpc.MSG_VR_PREPARE,
             _vr_req(vr_witness.OP_READ_VERIFY, 0, 0))]
    state, q, ql, _, _ = run_stack(stack, state, reqs)
    assert int(state["apps"]["vr"]["view"][0]) == 3
    body, _ = parse_reply(q, ql, 1)            # stale-view read rejected
    assert body[:4] == b"\x00\x00\x00\x01"


# ---------------------------------------------------------------------------
# LM serving engine


@pytest.fixture(scope="module")
def small_engine():
    cfg = get_smoke_config("internlm2-1.8b")
    params = model.init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.mark.slow
def test_engine_matches_plain_decode(small_engine):
    cfg, params = small_engine
    eng = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    prompt = np.arange(1, 9, dtype=np.int32)
    sid = eng.new_session(prompt)
    got = eng.generate(sid, 5)
    # oracle: plain greedy loop with init_cache
    cache = model.init_cache(cfg, 1, 32)
    logits, pcache = model.prefill(cfg, params, {"tokens": jnp.asarray(prompt)[None]})
    tok = model.greedy_token(cfg, logits)
    # install prefill cache into a 32-long cache by replaying decode steps
    cache = model.init_cache(cfg, 1, 32)
    toks = list(prompt) + [int(tok[0])]
    for t, x in enumerate(toks[:-1]):
        lg, cache = model.decode_step(cfg, params, cache,
                                      jnp.asarray([x], jnp.int32),
                                      jnp.int32(t))
    want = []
    cur = toks[-1]
    for i in range(5):
        lg, cache = model.decode_step(cfg, params, cache,
                                      jnp.asarray([cur], jnp.int32),
                                      jnp.int32(len(prompt) + i))
        cur = int(model.greedy_token(cfg, lg)[0])
        want.append(cur)
    assert got == want


@pytest.mark.slow
def test_session_migration_between_engines(small_engine):
    cfg, params = small_engine
    a = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    b = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    sid = a.new_session(prompt)
    first = a.generate(sid, 2)
    # migrate mid-generation; continuation must match a non-migrated run
    ref = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    rid = ref.new_session(prompt)
    ref_all = ref.generate(rid, 6)
    app_a, app_b = LmServerApp(a), LmServerApp(b)
    app_a.session_map[99] = sid
    app_a.migrate_session_to(99, app_b)
    rest = app_b.engine.generate(app_b.session_map[99], 4)
    assert first + rest == ref_all


@pytest.mark.slow
def test_lm_rpc_app_roundtrip(small_engine):
    cfg, params = small_engine
    app = LmServerApp(ServeEngine(cfg, params, max_sessions=2, max_seq=32))
    req = encode_request(7, 3, [5, 6, 7])
    reply = app.handle(req)
    session, toks, ok = decode_reply(reply)
    assert ok and session == 7 and len(toks) == 3
    assert all(0 <= t < cfg.vocab for t in toks)
