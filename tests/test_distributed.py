"""Multi-device tests on a small forced-host mesh: compressed cross-pod
psum (shard_map), sharded train-step consistency, elastic restore."""
import pytest

# These tests need >1 device; the shared `forced_host_mesh` fixture
# (tests/conftest.py -> repro.launch.hostmesh) runs the script in a
# subprocess with forced host devices so the rest of the suite keeps
# seeing 1 device, and skips cleanly when forcing is unavailable.

pytestmark = pytest.mark.slow

_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.compat import make_mesh, set_mesh

mesh = make_mesh((2, 4), ("pod", "data"))

# --- compressed cross-pod psum -------------------------------------------
from repro.optim import compress
with set_mesh(mesh):
    g = jax.random.normal(jax.random.key(0), (64,))
    r = jnp.zeros((64,))
    out, new_r = compress.compressed_psum_pod({"w": g}, {"w": r}, mesh)
    # replicated input -> compressed mean across pods ~= g
    err = float(jnp.abs(out["w"] - g).max() / jnp.abs(g).max())
    assert err < 0.02, f"compressed psum error {err}"
    # error feedback residual is bounded by one quantization step
    step = float(jnp.abs(g).max() / 127.0)
    assert float(jnp.abs(new_r["w"]).max()) <= step * 1.01
print("COMPRESS_OK")

# --- sharded vs single-device train step ----------------------------------
from repro.configs import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import model
from repro.optim import adamw
from repro.sharding import Policy, make_policy

cfg = get_smoke_config("internlm2-1.8b")
params = model.init_params(cfg, jax.random.key(0))
opt = adamw.init(params)
batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 16), 0, cfg.vocab),
         "labels": jax.random.randint(jax.random.key(2), (8, 16), 0, cfg.vocab)}

single = make_train_step(cfg, Policy())
p1, o1, m1 = jax.jit(single)(params, opt, batch)

mesh2 = make_mesh((4, 2), ("data", "model"))
with set_mesh(mesh2):
    pol = make_policy(mesh2)
    sharded = make_train_step(cfg, pol)
    p2, o2, m2 = jax.jit(sharded)(params, opt, batch)
d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 1e-4, f"sharded loss differs by {d}"
dmax = max(float(jnp.abs(a - b).max())
           for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert dmax < 1e-3, f"sharded params differ by {dmax}"
print("SHARDED_OK")

# --- elastic restore onto this mesh ---------------------------------------
import tempfile
from repro.ckpt import checkpoint as ckpt
with tempfile.TemporaryDirectory() as td:
    ckpt.save(td, 1, {"params": p1})
    sh = jax.tree.map(lambda _: NamedSharding(mesh2, P()), {"params": p1})
    back = ckpt.restore(td, {"params": p1}, shardings=sh)
    leaf = jax.tree.leaves(back["params"])[0]
    assert leaf.sharding.mesh.shape == {"data": 4, "model": 2}
print("ELASTIC_OK")
"""


@pytest.mark.parametrize("marker", ["COMPRESS_OK", "SHARDED_OK",
                                    "ELASTIC_OK"])
def test_multi_device_suite(marker, multi_device_output):
    assert marker in multi_device_output


@pytest.fixture(scope="module")
def multi_device_output(forced_host_mesh):
    return forced_host_mesh(_SCRIPT, devices=8)
