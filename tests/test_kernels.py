"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.kernels.checksum.kernel import checksum_pallas
from repro.kernels.checksum.ref import checksum_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.kernel import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rs_encode import gf
from repro.kernels.rs_encode.kernel import rs_encode_pallas
from repro.kernels.rs_encode.ref import rs_encode_np


# ---------------------------------------------------------------------------
# rs_encode


@pytest.mark.parametrize("k,p", [(8, 2), (4, 2), (10, 4), (6, 3)])
@pytest.mark.parametrize("n", [4096, 16384])
@pytest.mark.slow
def test_rs_encode_sweep(k, p, n):
    rng = np.random.default_rng(k * 100 + p)
    data = rng.integers(0, 256, (k, n), dtype=np.uint8)
    gm = gf.generator_matrix(k, p)
    bp = jnp.asarray(gf.bitplane_matrix(gm))
    got = rs_encode_pallas(jnp.asarray(data), bp, block=4096)
    want = rs_encode_np(data, gm)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_rs_zero_data_gives_zero_parity():
    gm = gf.generator_matrix(8, 2)
    bp = jnp.asarray(gf.bitplane_matrix(gm))
    out = rs_encode_pallas(jnp.zeros((8, 4096), jnp.uint8), bp)
    assert not np.asarray(out).any()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255), st.integers(0, 255))
def test_gf_field_axioms(a, b, c):
    m = gf.gf_mul
    assert m(a, b) == m(b, a)
    assert m(a, m(b, c)) == m(m(a, b), c)
    assert m(a, b ^ c) == m(a, b) ^ m(a, c)      # distributivity over XOR
    if a:
        assert m(a, gf.gf_inv(a)) == 1


# ---------------------------------------------------------------------------
# checksum


@pytest.mark.parametrize("B,L", [(1, 64), (7, 128), (32, 512), (9, 1500)])
def test_checksum_sweep(B, L):
    L = L + (L % 2)
    rng = np.random.default_rng(B * L)
    data = rng.integers(0, 256, (B, L), dtype=np.uint8)
    length = rng.integers(0, L + 1, (B,), dtype=np.int32)
    got = checksum_pallas(jnp.asarray(data), jnp.asarray(length))
    want = checksum_ref(jnp.asarray(data), jnp.asarray(length))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=0, max_size=200))
def test_checksum_property_verifies_to_zero(data):
    """Appending the checksum makes the ones-complement sum verify."""
    from repro.net.bytesops import np_checksum16
    cs = np_checksum16(data)
    padded = data + (b"\x00" if len(data) % 2 else b"") + bytes(
        [cs >> 8, cs & 0xFF])
    assert np_checksum16(padded) == 0


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("S,hd,kv,g,window", [
    (256, 64, 2, 1, 0), (512, 128, 1, 4, 0), (256, 64, 2, 2, 128),
    (512, 64, 4, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.slow
def test_flash_attention_sweep(S, hd, kv, g, window, dtype):
    B = 2
    key = jax.random.key(S + hd)
    q = (jax.random.normal(key, (B * kv * g, S, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(key, 1),
                           (B * kv, S, hd)) * 0.5).astype(dtype)
    v = (jax.random.normal(jax.random.fold_in(key, 2),
                           (B * kv, S, hd)) * 0.5).astype(dtype)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 bq=128, bk=128)
    want = attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_bidirectional():
    q = jax.random.normal(jax.random.key(0), (2, 256, 64))
    k = jax.random.normal(jax.random.key(1), (2, 256, 64))
    v = jax.random.normal(jax.random.key(2), (2, 256, 64))
    got = flash_attention_pallas(q, k, v, causal=False, bq=128, bk=128)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# mamba scan


@pytest.mark.parametrize("S,D,N", [(256, 64, 8), (512, 128, 16), (256, 32, 4)])
@pytest.mark.slow
def test_mamba_scan_sweep(S, D, N):
    B = 2
    key = jax.random.key(S * D)
    u = jax.random.normal(key, (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (B, S, D)) - 1.0)
    bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, N))
    cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, N))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 4), (D, N)))
    got = mamba_scan_pallas(u, dt, bm, cm, A, bd=32, bs=128)
    want = mamba_scan_ref(u, dt, bm, cm, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


def test_mamba_scan_state_carries_across_blocks():
    """With decay ~1 and constant input, h accumulates linearly across the
    whole sequence — catching any scratch reset between seq blocks."""
    B, S, D, N = 1, 512, 32, 4
    u = jnp.ones((B, S, D))
    dt = jnp.full((B, S, D), 1e-3)
    bm = jnp.ones((B, S, N))
    cm = jnp.ones((B, S, N))
    A = jnp.full((D, N), -1e-6)
    y = mamba_scan_pallas(u, dt, bm, cm, A, bd=32, bs=128)
    # y[t] ~ N * (t+1) * dt — strictly increasing across block boundaries
    yt = np.asarray(y[0, :, 0])
    assert (np.diff(yt) > 0).all()
    np.testing.assert_allclose(yt[-1] / yt[127], S / 128.0, rtol=1e-2)


# ---------------------------------------------------------------------------
# ops-level wrappers (model layout)


def test_flash_attention_ops_model_layout():
    from repro.kernels.flash_attention import ops as fops
    B, S, KV, G, hd = 2, 256, 2, 2, 64
    key = jax.random.key(0)
    q = jax.random.normal(key, (B, S, KV, G, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd)) * 0.5
    got = fops.flash_attention(q, k, v, causal=True, bq=128, bk=128)
    ref = fops.flash_attention(q, k, v, causal=True, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    # and against the model's own XLA attention path
    from repro.models import layers as L
    qg = q.reshape(B, S, KV, G, hd)
    want = L._attn_online(qg, k, v, jnp.arange(S), jnp.arange(S), 0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4,
                               rtol=2e-4)


def test_checksum_ops_jit_selectable():
    from repro.kernels.checksum import ops as cops
    rng = np.random.default_rng(5)
    data = jnp.asarray(rng.integers(0, 256, (4, 128), dtype=np.uint8))
    length = jnp.asarray([128, 0, 65, 7], jnp.int32)
    a = cops.checksum(data, length, use_pallas=True)
    b = cops.checksum(data, length, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
