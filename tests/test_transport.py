"""Loss-tolerant transport: the congestion-control engine
(repro/transport/cc), dispatch token buckets (repro/transport/rate), and
the management-plane hooks that expose both in-band.

The engine tests are frame-driven (golden Linux wire format in, engine
state + reply segments out) — the CC block is exercised through exactly
the hooks the compiled stack uses."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo
from repro.core import control, telemetry
from repro.mgmt.console import MgmtConsole
from repro.net import eth, frames as F, ipv4, rpc, tcp
from repro.net.stack import TcpStack, UdpStack, tcp_topology
from repro.transport import cc as ccmod, rate as rate_mod

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")
MP = 9909
MSS = 100


def rx(conn, frames, max_len=600):
    p, l = F.to_batch(frames, max_len)
    p, l = jnp.asarray(p), jnp.asarray(l)
    p, l, m = eth.parse(p, l)
    p, l, m2, ok = ipv4.parse(p, l)
    m.update(m2)
    d, dl, m = tcp.parse_segment(p, l, m)
    return tcp.rx_batch(conn, d, dl, m)


def establish(policy="newreno", seq0=5000):
    conn = tcp.init(max_conns=4, local_ip=IP_S, cc_policy=policy, mss=MSS)
    syn = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=seq0, ack=0,
                          flags=tcp.SYN)
    conn, r = rx(conn, [syn])
    iss = int(r["tcp_seq"][0])
    ack = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=seq0 + 1, ack=iss + 1,
                          flags=tcp.ACK)
    conn, _ = rx(conn, [ack])
    return conn, iss


def ack_frame(iss, acked, flags=tcp.ACK, seq=5001):
    return F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=seq,
                           ack=(iss + 1 + acked) & 0xFFFFFFFF, flags=flags)


def stage_and_emit(conn, nbytes, nsegs):
    conn, ok = tcp.app_send(conn, 0,
                            jnp.asarray([65] * nbytes, jnp.uint8), nbytes)
    assert bool(ok)
    for _ in range(nsegs):
        conn, seg, _, dlen = tcp.tx_emit(conn, 0, mss=MSS)
    return conn


# ---------------------------------------------------------------------------
# congestion window dynamics


def test_cc_initial_window_and_slow_start():
    conn, iss = establish()
    cc = conn["cc"]
    assert int(cc["cwnd"][0]) == ccmod.IW_SEGS * MSS
    conn = stage_and_emit(conn, 900, 9)
    # cumulative ACKs grow cwnd by min(acked, mss) in slow start
    for k in range(3):
        conn, _ = rx(conn, [ack_frame(iss, 300 * (k + 1))])
    assert int(conn["cc"]["cwnd"][0]) == ccmod.IW_SEGS * MSS + 3 * MSS
    assert int(conn["snd_una"][0]) == (iss + 901) & 0xFFFFFFFF


def test_cc_congestion_avoidance_after_ssthresh():
    conn, iss = establish()
    cc = dict(conn["cc"])
    cc["ssthresh"] = cc["ssthresh"].at[0].set(MSS)      # force CA regime
    conn = dict(conn)
    conn["cc"] = cc
    cwnd0 = int(cc["cwnd"][0])
    conn = stage_and_emit(conn, 300, 3)
    conn, _ = rx(conn, [ack_frame(iss, 300)])
    # CA growth: + mss*mss/cwnd (rounded down, >= 1), not + mss
    assert int(conn["cc"]["cwnd"][0]) == cwnd0 + max(MSS * MSS // cwnd0, 1)


def test_cc_rtt_estimator_drives_rto():
    conn, iss = establish()
    conn = stage_and_emit(conn, 200, 2)
    for _ in range(4):                  # 4 ticks of one-way-ish delay
        conn, _ = tcp.tick(conn)
    conn, _ = rx(conn, [ack_frame(iss, 200)])
    cc = conn["cc"]
    assert int(cc["srtt"][0]) >> 3 == 4
    # RTO = SRTT + max(4*RTTVAR, 1 tick), floored/capped
    assert ccmod.RTO_MIN <= int(cc["rto"][0]) <= ccmod.RTO_MAX
    assert int(cc["rto"][0]) == 4 + 8   # rttvar = rtt/2 on first sample
    assert int(cc["rtt_pending"][0]) == 0


def test_cc_fast_recovery_entry_exit_and_dup_ack_reset():
    conn, iss = establish()
    conn = stage_and_emit(conn, 500, 5)
    dup = ack_frame(iss, 0)
    conn, r = rx(conn, [dup, dup, dup])
    assert bool(r["fast_retx"][2])
    cc = conn["cc"]
    assert int(cc["in_rec"][0]) == 1
    assert int(cc["ssthresh"][0]) == max(500 // 2, 2 * MSS)
    assert int(cc["cwnd"][0]) == int(cc["ssthresh"][0]) + 3 * MSS
    assert int(cc["retx_fast"][0]) == 1
    # partial ACK: stays in recovery, asks for another retransmit
    conn, r = rx(conn, [ack_frame(iss, 200)])
    assert bool(r["fast_retx"][0]) and int(conn["cc"]["in_rec"][0]) == 1
    # full ACK: exits, deflates to ssthresh, dup-ACK counter resets
    conn, r = rx(conn, [ack_frame(iss, 500)])
    assert int(conn["cc"]["in_rec"][0]) == 0
    assert int(conn["cc"]["cwnd"][0]) == int(conn["cc"]["ssthresh"][0])
    assert int(conn["dup_acks"][0]) == 0


def test_cc_timer_expiry_collapses_window_and_backs_off():
    conn, iss = establish()
    conn = stage_and_emit(conn, 200, 2)
    rto0 = int(conn["cc"]["rto"][0])
    for _ in range(rto0):
        conn, expired = tcp.tick(conn)
    assert bool(expired[0])
    cc = conn["cc"]
    assert int(cc["cwnd"][0]) == MSS
    assert int(cc["rto"][0]) == min(rto0 * 2, ccmod.RTO_MAX)
    assert int(cc["retx_timer"][0]) == 1
    assert int(conn["snd_nxt"][0]) == int(conn["snd_una"][0])  # go-back-N


def test_tx_emit_fast_vs_timer_retransmit_paths():
    """Satellite: the two retransmit paths are distinct — fast resends one
    MSS and leaves snd_nxt alone; timer restarts go-back-N."""
    conn, iss = establish(policy=None)
    conn = stage_and_emit(conn, 300, 3)
    nxt0 = int(conn["snd_nxt"][0])
    conn, seg, data, dlen = tcp.tx_emit(conn, 0, mss=MSS, retransmit="fast")
    assert int(seg["tcp_seq"]) == (iss + 1) & 0xFFFFFFFF
    assert int(dlen) == MSS
    assert int(conn["snd_nxt"][0]) == nxt0          # untouched
    conn, seg, data, dlen = tcp.tx_emit(conn, 0, mss=MSS, retransmit="timer")
    assert int(seg["tcp_seq"]) == (iss + 1) & 0xFFFFFFFF
    # go-back-N restart: transmission resumes right after this segment
    assert int(conn["snd_nxt"][0]) == (iss + 1 + MSS) & 0xFFFFFFFF
    # retransmit=True keeps its old (fast) meaning
    conn, seg, _, _ = tcp.tx_emit(conn, 0, mss=MSS, retransmit=True)
    assert int(seg["tcp_seq"]) == (iss + 1) & 0xFFFFFFFF


def test_cwnd_gates_tx_emit():
    conn, iss = establish()
    cc = dict(conn["cc"])
    cc["cwnd"] = cc["cwnd"].at[0].set(150)
    conn = dict(conn)
    conn["cc"] = cc
    conn, _ = tcp.app_send(conn, 0, jnp.asarray([65] * 400, jnp.uint8), 400)
    conn, seg, _, dlen = tcp.tx_emit(conn, 0, mss=MSS)
    assert int(dlen) == MSS
    conn, seg, _, dlen = tcp.tx_emit(conn, 0, mss=MSS)
    assert int(dlen) == 50                          # cwnd-limited
    conn, seg, _, dlen = tcp.tx_emit(conn, 0, mss=MSS)
    assert int(dlen) == 0


# ---------------------------------------------------------------------------
# ECN


def test_ece_newreno_cuts_once_per_window():
    conn, iss = establish()
    conn = stage_and_emit(conn, 600, 6)
    cwnd0 = int(conn["cc"]["cwnd"][0])
    conn, _ = rx(conn, [ack_frame(iss, 100, flags=tcp.ACK | tcp.ECE)])
    cc = conn["cc"]
    assert int(cc["marks"][0]) == 1
    assert int(cc["cwnd"][0]) == max(cwnd0 // 2, 2 * MSS)
    # second ECE in the same window: no further cut
    cut = int(cc["cwnd"][0])
    conn, _ = rx(conn, [ack_frame(iss, 200, flags=tcp.ACK | tcp.ECE)])
    assert int(conn["cc"]["cwnd"][0]) >= cut        # only additive growth


def test_ece_dctcp_alpha_tracks_mark_fraction():
    conn, iss = establish(policy="dctcp")
    conn = stage_and_emit(conn, 600, 6)
    # a fully-marked window pushes alpha up by F/16 per boundary
    acked = 0
    for k in range(6):
        acked += 100
        conn, _ = rx(conn, [ack_frame(iss, acked,
                                      flags=tcp.ACK | tcp.ECE)])
    cc = conn["cc"]
    assert int(cc["marks"][0]) == 6
    assert int(cc["alpha"][0]) > 0
    assert int(cc["cwnd"][0]) < ccmod.IW_SEGS * MSS + 6 * MSS  # got cut


def test_receiver_echoes_ce_mark_as_ece():
    conn, iss = establish()
    seg = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=5001, ack=iss + 1,
                          flags=tcp.ACK | tcp.PSH, payload=b"marked")
    # set CE in the IP header (offset 14+1) and re-fix the checksum
    from repro.netem.link import _ce_mark
    conn, r = rx(conn, [_ce_mark(seg)])
    assert bool(r["emit"][0])
    assert int(r["tcp_flags"][0]) & tcp.ECE
    # unmarked data is acked without ECE
    seg2 = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=5007, ack=iss + 1,
                           flags=tcp.ACK | tcp.PSH, payload=b"clean!")
    conn, r = rx(conn, [seg2])
    assert not (int(r["tcp_flags"][0]) & tcp.ECE)


# ---------------------------------------------------------------------------
# migration + tile parameter


def test_cc_state_migrates_with_connection():
    conn, iss = establish()
    cc = dict(conn["cc"])
    cc["cwnd"] = cc["cwnd"].at[0].set(777)
    cc["srtt"] = cc["srtt"].at[0].set(40)
    conn = dict(conn)
    conn["cc"] = cc
    blob = tcp.serialize_conn(conn, 0)
    target = tcp.init(max_conns=4, local_ip=IP_S, cc_policy="newreno",
                      mss=MSS)
    target = tcp.install_conn(target, 2, blob)
    assert int(target["cc"]["cwnd"][2]) == 777
    assert int(target["cc"]["srtt"][2]) == 40


def test_cc_policy_is_a_tile_parameter():
    """NewReno vs DCTCP vs the bare seed engine differ only in the
    topology (a TileDecl param on tcp_rx) — and the param survives the
    config (de)serialization round trip."""
    topo = tcp_topology(cc_policy="dctcp")
    assert topo.tile("tcp_rx").params == {"cc_policy": "dctcp"}
    topo2 = topo.from_dict(topo.to_dict())
    assert topo2.tile("tcp_rx").params == {"cc_policy": "dctcp"}

    stack = TcpStack(IP_S, topo=topo2, max_conns=4)
    st = stack.init_state()
    assert int(st["conn"]["cc"]["policy"]) == ccmod.DCTCP
    assert ccmod.log_name(0) in st["telemetry"]["logs"]
    # no param -> the seed engine, with no CC state anywhere
    bare = TcpStack(IP_S, max_conns=4)
    assert "cc" not in bare.init_state()["conn"]


# ---------------------------------------------------------------------------
# token-bucket rate limiting (satellite)


def test_rate_bucket_refill_and_burst():
    rt = rate_mod.init()
    rt = rate_mod.set_slot(rt, 0, 7, rate=2, burst=4)
    port = jnp.full((6,), 7, jnp.uint32)
    arrived = jnp.ones((6,), bool)
    rt, ok = rate_mod.apply(rt, port, arrived)
    assert np.asarray(ok).tolist() == [True] * 4 + [False, False]
    # next batch: only the refill (2 tokens) is available
    rt, ok = rate_mod.apply(rt, port, arrived)
    assert np.asarray(ok).tolist() == [True] * 2 + [False] * 4
    # other ports are never limited
    rt, ok = rate_mod.apply(rt, jnp.full((3,), 9, jnp.uint32),
                            jnp.ones((3,), bool))
    assert np.asarray(ok).tolist() == [True] * 3


# ---------------------------------------------------------------------------
# management plane: RATE_SET / LOG_READ_RANGE / CC knobs (satellites)


def batch(frames, max_len=256):
    p, l = F.to_batch(frames, max_len)
    return jnp.asarray(p), jnp.asarray(l)


def echo_frame(sport, req=1):
    return F.udp_rpc_frame(IP_C, IP_S, sport, 7,
                           rpc.np_frame(rpc.MSG_ECHO, req, b"x"))


@pytest.fixture(scope="module")
def udp_stack():
    return UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)


def test_rate_set_limits_port_live_and_clears(udp_stack):
    stack = udp_stack
    state = stack.init_state()
    con = MgmtConsole(stack)
    state, r = con.set_rate(state, 0, 7, 2)
    assert r["status"] == 1
    frames = [echo_frame(5000 + i, i) for i in range(5)]
    state, _, _, alive, info = stack.rx_tx(state, *batch(frames))
    assert np.asarray(alive).tolist() == [True, True, False, False, False]
    # the drops are visible in udp_rx's telemetry counters
    row = np.asarray(telemetry.entry_at(
        stack.pipeline.node_log(state, "udp_rx"), 0))
    assert row[2] == 3
    state, r = con.clear_rate(state, 0)
    assert r["status"] == 1
    state, _, _, alive, _ = stack.rx_tx(state, *batch(frames))
    assert np.asarray(alive).tolist() == [True] * 5


def test_rate_set_burst_allows_transient(udp_stack):
    stack = udp_stack
    state = stack.init_state()
    con = MgmtConsole(stack)
    state, r = con.set_rate(state, 1, 7, 1, burst=3)
    assert r["status"] == 1
    frames = [echo_frame(5000 + i, i) for i in range(4)]
    state, _, _, alive, _ = stack.rx_tx(state, *batch(frames))
    assert np.asarray(alive).tolist() == [True, True, True, False]
    state, _, _, alive, _ = stack.rx_tx(state, *batch(frames))
    assert np.asarray(alive).tolist() == [True, False, False, False]


def test_log_read_range_streams_rows(udp_stack):
    """Satellite: one LOG_READ_RANGE frame returns what would take
    `count` one-row LOG_READ round trips."""
    stack = udp_stack
    state = stack.init_state()
    con = MgmtConsole(stack)
    for k in range(5):
        state, *_ = stack.rx_tx(state, *batch([echo_frame(6000 + k)]))
    # readback serves rows through the *previous* batch (the fused node
    # append lands at batch egress), so start=0 is the newest data batch
    state, r = con.read_log_range(state, "eth_rx", start=0, count=4)
    assert r["status"] == 4 and len(r["rows"]) == 4
    want = np.asarray(telemetry.latest(
        stack.pipeline.node_log(state, "eth_rx"), 5))[:4][::-1]
    got = np.asarray(r["rows"])
    np.testing.assert_array_equal(got, want[:, :control.ROW_WORDS])


def test_log_read_range_respects_req_buf(udp_stack):
    stack = udp_stack
    state = stack.init_state()
    con = MgmtConsole(stack)
    state, *_ = stack.rx_tx(state, *batch([echo_frame(5000)]))
    state, *_ = stack.rx_tx(state, *batch([echo_frame(5001)]))
    eth_id = con.node_ids["eth_rx"]
    reads = [(control.OP_LOG_READ_RANGE, 0, eth_id, 0, 2)] * \
        (telemetry.REQ_BUF + 1)
    state, resps = con.roundtrip(state, reads)
    # each range occupies ONE slot; the overflow request is dropped
    assert [r["status"] for r in resps] == [2] * telemetry.REQ_BUF + [0]


@pytest.fixture(scope="module")
def tcp_cc_stack():
    return TcpStack(IP_S, mgmt_port=MP, cc_policy="newreno", max_conns=4)


def _establish_on_stack(stack, state):
    syn = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=900, ack=0,
                          flags=tcp.SYN)
    state, resps, *_ = stack.rx_mgmt(state, *batch([syn]))
    iss = int(resps["tcp_seq"][0])
    ack = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=901, ack=iss + 1,
                          flags=tcp.ACK)
    state, *_ = stack.rx_mgmt(state, *batch([ack]))
    return state, iss


def test_cc_counters_readable_in_band(tcp_cc_stack):
    """Acceptance: cwnd/ssthresh/rtt for a live connection over LOG_READ."""
    stack = tcp_cc_stack
    state = stack.init_state()
    state, iss = _establish_on_stack(stack, state)
    # cc logging must not orphan the executor's node counters: the tile
    # logs saw the same 2 batches the engine did
    assert int(stack.rx_pipe.node_log(state, "tcp_rx").wr) == 2
    assert int(np.asarray(telemetry.entry_at(
        stack.rx_pipe.node_log(state, "tcp_rx"), 0))[1]) == 1  # packets_in
    con = MgmtConsole(stack)
    state, r = con.read_cc(state, 0)
    assert r["status"] == 1
    assert r["cc"]["cwnd"] == int(state["conn"]["cc"]["cwnd"][0])
    assert r["cc"]["ssthresh"] == \
        min(int(state["conn"]["cc"]["ssthresh"][0]), 0x7FFFFFFF)
    assert r["cc"]["srtt"] == int(state["conn"]["cc"]["srtt"][0]) >> 3
    assert r["cc"]["retx"] == 0 and r["cc"]["marks"] == 0


def test_cc_knobs_settable_in_band(tcp_cc_stack):
    stack = tcp_cc_stack
    state = stack.init_state()
    state, iss = _establish_on_stack(stack, state)
    con = MgmtConsole(stack)
    state, rs = con.set_cc_window(state, 0, cwnd=3333, ssthresh=4444)
    assert [r["status"] for r in rs] == [1, 1]
    assert int(state["conn"]["cc"]["cwnd"][0]) == 3333
    assert int(state["conn"]["cc"]["ssthresh"][0]) == 4444
    state, r = con.set_cc_policy(state, "dctcp")
    assert r["status"] == 1
    assert int(state["conn"]["cc"]["policy"]) == ccmod.DCTCP
    # rejected knob: unknown conn index
    state, (r,) = con.roundtrip(state, [(control.OP_CC_SET, 99, 1, 1, 0)])
    assert r["status"] == 0
