"""The HLO cost walker is the framework's profiler — test it directly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_walk as W
from repro.launch.hlo_analysis import Roofline


def _walk_fn(fn, *args):
    return W.walk(jax.jit(fn).lower(*args).compile().as_text())


def test_scan_flops_multiply_by_trip_count():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def scan8(w, x):
        return jax.lax.scan(body, x, w)[0]

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r8 = _walk_fn(scan8, w, x)
    expect = 8 * 2 * 128 * 128 * 128
    assert abs(r8.flops - expect) / expect < 0.05
    # XLA's own cost_analysis undercounts by ~8x (the bug we fixed)
    xla = jax.jit(scan8).lower(w, x).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):   # older jax returns one dict per device
        xla = xla[0]
    assert xla["flops"] < r8.flops / 4


def test_nested_scan_multiplicity():
    def inner(c, x):
        return c + jnp.sin(x), None

    def outer(c, xs):
        c2, _ = jax.lax.scan(inner, c, xs)
        return c2, None

    def f(xss):
        z = jnp.zeros((16,))
        out, _ = jax.lax.scan(outer, z, xss)
        return out

    xss = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    r = _walk_fn(f, xss)
    # 4*8 = 32 sin evaluations of 16 elems, 4 flops each in our model
    assert r.flops >= 32 * 16 * 4


def test_dot_flops_from_contracting_dims():
    def f(a, b):
        return jnp.einsum("ij,kj->ik", a, b)   # contraction over j=64

    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    r = _walk_fn(f, a, b)
    expect = 2 * 32 * 16 * 64
    assert abs(r.flops - expect) / expect < 0.2


def test_comment_laden_tuple_types_parse():
    # regression: /*index=N*/ comments inside tuple types broke parsing
    text = """
HloModule m
%body (p: (s32[], f32[8,8], /*index=2*/f32[4,8,8])) -> (s32[], f32[8,8], /*index=2*/f32[4,8,8]) {
  %p = (s32[], f32[8,8]{1,0}, /*index=2*/f32[4,8,8]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[4,8,8]{2,1,0} get-tuple-element(%p), index=2
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[8,8], /*index=2*/f32[4,8,8]) tuple(%i, %d, %w)
}
%cond (p: (s32[], f32[8,8], /*index=2*/f32[4,8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}, /*index=2*/f32[4,8,8]{2,1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}
ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %w0 = f32[4,8,8]{2,1,0} constant(0)
  %t0 = (s32[], f32[8,8], /*index=2*/f32[4,8,8]) tuple(%z, %a, %w0)
  %wh = (s32[], f32[8,8], /*index=2*/f32[4,8,8]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"4"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%wh), index=1
}
"""
    r = W.walk(text)
    assert r.n_while == 1 and r.unknown_trip == 0
    # dot flops dominate (cond compares add a few elementwise flops)
    assert r.flops == pytest.approx(4 * 2 * 8 * 8 * 8, rel=0.02)


def test_collective_link_bytes_ring_factors():
    text = """
HloModule m
ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  ROOT %ar = f32[64,64]{1,0} all-reduce(%a), replica_groups=[2,8]<=[16], to_apply=%add
}
"""
    r = W.walk(text)
    full = 64 * 64 * 4
    assert r.coll_link_bytes == pytest.approx(2 * full * 7 / 8)


def test_roofline_terms_and_bottleneck():
    ro = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=0,
                  model_flops=98.5e12)
    assert ro.bottleneck == "memory"
    assert ro.t_memory == pytest.approx(2.0)
    assert ro.roofline_fraction == pytest.approx(0.25)
