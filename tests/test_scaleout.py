"""RSS tile replication: compiler lowering of `scaleout.replicate`
groups, un-lowerable-group diagnostics, live drain/restore with zero
frame loss and no retrace, GROUP_READ readback, flow-hash lane balance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo
from repro.core import control, scaleout
from repro.core.compiler import CompileError, StackCompiler
from repro.mgmt.console import MgmtConsole
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, replicated_udp_topology, udp_topology

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")


def _stack(n_rx=2, policy="flow_hash", mgmt=9909):
    apps = [echo.make(port=7)]
    topo = replicated_udp_topology(apps, n_rx=n_rx, policy=policy)
    return UdpStack(apps, IP_S, topo=topo, mgmt_port=mgmt)


def _flow_frames(ports, per_flow=2, payload=b"x" * 16):
    frames = []
    for p in ports:
        for i in range(per_flow):
            frames.append(F.udp_rpc_frame(IP_C, IP_S, p, 7,
                                          rpc.np_frame(rpc.MSG_ECHO, i,
                                                       payload)))
    return frames


# ---------------------------------------------------------------------------
# lowering


def test_replicated_topology_compiles_and_groups():
    st = _stack()
    meta = st.pipeline.pipe_meta
    assert "udp_rx" in meta["groups"]
    # the group lowers to ONE node named after it; members are gone
    assert "udp_rx" in meta["order"]
    assert not any(n.startswith("udp_rx.r") for n in meta["order"])


def test_replicated_egress_bit_identical_to_unreplicated():
    apps = [echo.make(port=7)]
    plain = UdpStack(apps, IP_S, topo=udp_topology(apps), mgmt_port=9909)
    repl = _stack(n_rx=2)
    frames = _flow_frames(range(5000, 5008))
    p, l = F.to_batch(frames, 256)
    p, l = jnp.asarray(p), jnp.asarray(l)
    s0, q0, ql0, a0, _ = plain.rx_tx(plain.init_state(), p, l)
    s1, q1, ql1, a1, info = repl.rx_tx(repl.init_state(), p, l)
    assert np.array_equal(np.asarray(q0), np.asarray(q1))
    assert np.array_equal(np.asarray(ql0), np.asarray(ql1))
    assert np.array_equal(np.asarray(a0), np.asarray(a1))
    assert int(np.asarray(a1).sum()) == len(frames)


def test_flow_hash_lanes_distribute_and_stick():
    st = _stack(n_rx=2)
    frames = _flow_frames(range(5000, 5016), per_flow=2)
    p, l = F.to_batch(frames, 256)
    state, _, _, _, info = st.rx_tx(st.init_state(),
                                    jnp.asarray(p), jnp.asarray(l))
    lanes = np.asarray(info["udp_rx.lane"])
    # per-flow stickiness: both frames of a flow take the same lane
    assert np.array_equal(lanes[0::2], lanes[1::2])
    # balance: the avalanche-finalized hash spreads 16 flows over 2 lanes
    counts = np.bincount(lanes[lanes >= 0], minlength=2)
    assert counts.min() >= 4, counts
    # the dispatch state accounts every predicated frame
    served = np.asarray(state["dispatch"]["udp_rx"].served)
    assert served.sum() >= len(frames)


# ---------------------------------------------------------------------------
# un-lowerable groups raise clear errors naming the group (regression:
# these used to compile silently with the group's routes dangling)


def _topo_with_group(**edit):
    apps = [echo.make(port=7)]
    topo = replicated_udp_topology(apps, n_rx=2)
    topo.replica_groups["udp_rx"].update(edit)
    return topo, apps


@pytest.mark.parametrize("edit,needle", [
    ({"members": []}, "no members"),
    ({"policy": "bogus"}, "un-lowerable dispatch policy"),
    ({"policy": "port_match", "base_port": None}, "no base_port"),
    ({"kind": "mgmt"}, "cannot be lowered"),
])
def test_unlowerable_group_raises_naming_group(edit, needle):
    topo, apps = _topo_with_group(**edit)
    with pytest.raises(CompileError) as e:
        StackCompiler(topo, bindings={a.name: a for a in apps},
                      options={"local_ip": IP_S}).compile("eth_rx")
    assert "udp_rx" in str(e.value)
    assert needle in str(e.value)


def test_group_member_kind_mismatch_raises():
    topo, apps = _topo_with_group()
    # corrupt one member to a different kind
    bad = topo.tile(topo.replica_groups["udp_rx"]["members"][1])
    bad.kind = "ip_rx"
    with pytest.raises(CompileError, match="mixes kinds"):
        StackCompiler(topo, bindings={a.name: a for a in apps},
                      options={"local_ip": IP_S}).compile("eth_rx")


def test_replicate_refuses_unknown_policy_at_dispatch():
    d = scaleout.make_dispatch([0, 1])
    with pytest.raises(ValueError, match="unknown dispatch policy"):
        scaleout.dispatch_lane(d, "bogus", {}, jnp.ones((4,), bool))


# ---------------------------------------------------------------------------
# live drain / restore: mid-stream, zero loss, zero retrace


def test_drain_rehashes_to_survivors_mid_stream_no_loss_no_retrace():
    st = _stack(n_rx=2)
    con = MgmtConsole(st)
    ports = list(range(5000, 5016))
    frames = _flow_frames(ports, per_flow=2)
    p, l = F.to_batch(frames, 256)
    p, l = jnp.asarray(p), jnp.asarray(l)

    traces = []

    def counted(s, pp, ll):
        traces.append(1)
        return st.rx_tx(s, pp, ll)

    fn = jax.jit(counted)
    state = st.init_state()

    # phase 1: both replicas up
    state, q, ql, alive, info = fn(state, p, l)
    lanes0 = np.asarray(info["udp_rx.lane"])
    assert int(np.asarray(alive).sum()) == len(frames)
    assert set(np.unique(lanes0[lanes0 >= 0])) == {0, 1}

    # drain replica 0 in-band (the command batch reuses the same shapes,
    # so it must hit the same compiled executable)
    state, r = con.drain_replica(state, "udp_rx", 0)
    assert r["status"] == 1

    # phase 2: same traffic — every flow re-hashes onto the survivor,
    # with ZERO dropped frames
    state, q, ql, alive, info = fn(state, p, l)
    lanes1 = np.asarray(info["udp_rx.lane"])
    assert int(np.asarray(alive).sum()) == len(frames)
    assert set(np.unique(lanes1[lanes1 >= 0])) == {1}

    # restore re-admits: lanes return to the original assignment
    state, r = con.restore_replica(state, "udp_rx", 0)
    assert r["status"] == 1
    state, q, ql, alive, info = fn(state, p, l)
    lanes2 = np.asarray(info["udp_rx.lane"])
    assert int(np.asarray(alive).sum()) == len(frames)
    assert np.array_equal(lanes2, lanes0)

    # the dataplane fn traced exactly once: drain/restore are runtime
    # table writes, never a recompilation (TRACE_SET/ROUTE_SET discipline)
    assert len(traces) == 1


def test_drain_during_run_stream_zero_loss():
    st = _stack(n_rx=2)
    con = MgmtConsole(st)
    ports = list(range(5000, 5008))
    frames = _flow_frames(ports, per_flow=4)
    arena = F.FrameArena(4, len(ports) * 4 // 4, 256)
    arena.fill(frames)
    state = st.init_state()
    state, outs = st.run_stream(state, jnp.asarray(arena.payload),
                                jnp.asarray(arena.length))
    assert int(np.asarray(outs["alive"]).sum()) == len(frames)

    state, r = con.drain_replica(state, "udp_rx", 0)
    assert r["status"] == 1
    state, outs = st.run_stream(state, jnp.asarray(arena.payload),
                                jnp.asarray(arena.length))
    # all frames survive the drain, on the surviving lane only
    assert int(np.asarray(outs["alive"]).sum()) == len(frames)
    lanes = np.asarray(outs["info"]["udp_rx.lane"])
    assert set(np.unique(lanes[lanes >= 0])) == {1}


# ---------------------------------------------------------------------------
# GROUP_READ readback


def test_group_read_serves_health_and_served_counters():
    st = _stack(n_rx=2)
    con = MgmtConsole(st)
    state = st.init_state()
    frames = _flow_frames(range(5000, 5016))
    p, l = F.to_batch(frames, 256)
    state, *_ = st.rx_tx(state, jnp.asarray(p), jnp.asarray(l))

    state, r = con.read_group(state, "udp_rx")
    g = r["group"]
    assert g["n_replicas"] == 2
    assert g["healthy"] == [True, True]
    assert sum(g["served"]) >= len(frames)
    assert min(g["served"]) > 0          # RSS actually spread the flows

    state, _ = con.drain_replica(state, "udp_rx", 0)
    state, r = con.read_group(state, "udp_rx")
    assert r["group"]["healthy"] == [False, True]


def test_serve_group_row_encoding():
    healthy = jnp.asarray([True, False, True])
    served = jnp.asarray([7, 0, 9], jnp.int32)
    row, n = control.serve_group_row(healthy, served,
                                     jnp.ones((), bool))
    row = np.asarray(row)
    assert row[0] == 3
    assert row[1] == 0b101
    assert list(row[2:5]) == [7, 0, 9]
    assert int(n) == 5
    row0, n0 = control.serve_group_row(healthy, served,
                                       jnp.zeros((), bool))
    assert int(n0) == 0 and not np.asarray(row0).any()


# ---------------------------------------------------------------------------
# lint coverage over replicated topologies


def test_lint_covers_replica_group_kinds():
    from repro.obs import lint
    topo = replicated_udp_topology([echo.make(port=7)], n_rx=2)
    assert lint.check_topology_coverage(topo) == []
