"""Topology-compiled executor: golden-batch equivalence against the
pre-refactor hand-written chains, topology-only tile insertion (NAT into
the UDP stack), telemetry counters, and RingLog wraparound.

The reference functions below are verbatim ports of the hand-written
`UdpStack.rx_tx` / `TcpStack.rx` / `TcpStack.tx_frame` pipelines from
before the StackCompiler refactor — the compiled executor must reproduce
them bit for bit on golden packet batches."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo, reed_solomon, vr_witness
from repro.core import telemetry
from repro.core.compiler import StackCompiler
from repro.core.scaleout import (by_flow_hash, by_port, make_dispatch,
                                 round_robin)
from repro.net import eth, frames as F, ipv4, nat as nat_mod, rpc, tcp, udp
from repro.net.stack import TcpStack, UdpStack, tcp_topology, udp_topology

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")
VIP = F.ip("20.0.0.9")


# ---------------------------------------------------------------------------
# reference implementations (the pre-refactor hand-written chains)


def ref_udp_init_state(apps):
    st = {"dispatch": {}, "apps": {}, "rx_count": jnp.zeros((), jnp.int32)}
    for a in apps:
        st["dispatch"][a.name] = make_dispatch(list(range(a.n_replicas)))
        st["apps"][a.name] = a.state
    return st


def ref_udp_rx_tx(apps, state, payload, length):
    p, l, m = eth.parse(payload, length)
    is_ip = m["ethertype"] == eth.ETHERTYPE_IPV4
    p, l, m2, ok_ip = ipv4.parse(p, l)
    m.update(m2)
    is_udp = m["ip_proto"] == ipv4.PROTO_UDP
    p, l, m3, ok_udp = udp.parse(p, l, m)
    m = m3
    alive = is_ip & ok_ip & is_udp & ok_udp

    body, blen, rmeta, ok_rpc = rpc.parse(p, l)
    m.update(rmeta)
    alive &= ok_rpc

    out_body = body
    out_blen = blen
    info = {}
    for a in apps:
        at_app = alive & (m["dst_port"] == a.port) if a.policy != \
            "port_match" else alive & (m["dst_port"] >= a.port) & \
            (m["dst_port"] < a.port + a.n_replicas)
        d = state["dispatch"][a.name]
        if a.policy == "round_robin":
            d, replica_tile = round_robin(d, at_app)
        elif a.policy == "flow_hash":
            replica_tile = by_flow_hash(d, m)
        else:
            replica_tile = by_port(d, m["dst_port"], a.port)
        d = dataclasses.replace(
            d, served=d.served.at[replica_tile].add(at_app.astype(jnp.int32)))
        state["dispatch"][a.name] = d
        ast = state["apps"][a.name]
        ast, nb, nl = a.process(ast, body, blen, m, at_app, replica_tile)
        state["apps"][a.name] = ast
        out_body = jnp.where(at_app[:, None], nb, out_body)
        out_blen = jnp.where(at_app, nl, out_blen)
        info[a.name] = at_app

    q, ql = rpc.build(out_body, out_blen, m["msg_type"], m["req_id"])
    mtx = dict(m)
    mtx["src_ip"], mtx["dst_ip"] = m["dst_ip"], m["src_ip"]
    mtx["src_port"], mtx["dst_port"] = m["dst_port"], m["src_port"]
    mtx["ip_proto"] = jnp.full_like(m["src_ip"], ipv4.PROTO_UDP)
    q, ql = udp.build(q, ql, mtx)
    q, ql = ipv4.build(q, ql, mtx)
    mtx["eth_dst_hi"], mtx["eth_dst_lo"] = m["eth_src_hi"], m["eth_src_lo"]
    mtx["eth_src_hi"], mtx["eth_src_lo"] = m["eth_dst_hi"], m["eth_dst_lo"]
    q, ql = eth.build(q, ql, mtx)
    state["rx_count"] = state["rx_count"] + alive.sum(dtype=jnp.int32)
    return state, q, ql, alive, info


def ref_tcp_rx(state, payload, length, with_nat):
    p, l, m = eth.parse(payload, length)
    p, l, m2, ok = ipv4.parse(p, l)
    m.update(m2)
    if with_nat:
        m, _ = nat_mod.rx(state["nat"], m)
    data, dlen, m = tcp.parse_segment(p, l, m)
    conn, resps = tcp.rx_batch(state["conn"], data, dlen, m)
    state = dict(state)
    state["conn"] = conn
    return state, resps


def ref_tcp_tx_frame(state, seg_meta, data, dlen, with_nat):
    # (the seed's tx_frame translated 0-d metas, which nat._translate can't
    # index; batching first is value-identical and actually runs)
    m = {k: (v.reshape(1) if v.ndim == 0 else v)
         for k, v in seg_meta.items()}
    if with_nat:
        m, _ = nat_mod.tx(state["nat"], m)
    payload = data.reshape(1, -1) if data.ndim == 1 else data
    q, ql = tcp.build_segment(
        payload, dlen.reshape(1) if dlen.ndim == 0 else dlen,
        {k: v for k, v in m.items()
         if k in ("src_ip", "dst_ip", "src_port", "dst_port", "tcp_seq",
                  "tcp_ack", "tcp_flags", "tcp_wnd")})
    mm = dict(m)
    mm["ip_proto"] = jnp.full((q.shape[0],), ipv4.PROTO_TCP, jnp.uint32)
    q, ql = ipv4.build(q, ql, mm)
    return q, ql


# ---------------------------------------------------------------------------
# golden batches


def golden_udp_batch(max_len=4400):
    frames = [
        F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                        rpc.np_frame(rpc.MSG_ECHO, 1, b"ping-0")),
        F.udp_rpc_frame(IP_C, IP_S, 5001, 7,
                        rpc.np_frame(rpc.MSG_ECHO, 2, b"ping-1")),
        F.udp_rpc_frame(IP_C, IP_S, 5002, 9000,
                        rpc.np_frame(rpc.MSG_RS_ENCODE, 3, bytes(4096))),
        F.udp_rpc_frame(IP_C, IP_S, 5003, 9102,
                        rpc.np_frame(rpc.MSG_VR_PREPARE, 4,
                                     np.uint32([1, 0, 1, 0]).byteswap()
                                     .tobytes())),
        F.udp_rpc_frame(IP_C, IP_S, 5004, 4444,      # unknown port
                        rpc.np_frame(rpc.MSG_ECHO, 5, b"drop-me")),
    ]
    corrupt = bytearray(
        F.udp_rpc_frame(IP_C, IP_S, 5005, 7,
                        rpc.np_frame(rpc.MSG_ECHO, 6, b"bad")))
    corrupt[20] ^= 0xFF                              # IP checksum broken
    frames.append(bytes(corrupt))
    payload, length = F.to_batch(frames, max_len)
    return jnp.asarray(payload), jnp.asarray(length)


def make_apps():
    return [echo.make(port=7, n_replicas=2),
            reed_solomon.make(port=9000, n_replicas=4),
            vr_witness.make(base_port=9100, n_shards=4)]


def assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = {jax.tree_util.keystr(k): v
          for k, v in jax.tree_util.tree_leaves_with_path(b)}
    assert len(la) == len(lb)
    for k, v in la:
        np.testing.assert_array_equal(np.asarray(v),
                                      np.asarray(lb[jax.tree_util.keystr(k)]),
                                      err_msg=jax.tree_util.keystr(k))


# ---------------------------------------------------------------------------
# UDP equivalence (multi-app, multi-replica, all three dispatch policies)


@pytest.mark.parametrize("jit", [False, True], ids=["eager", "jit"])
def test_udp_compiled_matches_handwritten(jit):
    apps_c, apps_r = make_apps(), make_apps()
    stack = UdpStack(apps_c, IP_S, with_telemetry=False)
    payload, length = golden_udp_batch()

    fn = jax.jit(stack.rx_tx) if jit else stack.rx_tx
    st_c, q_c, ql_c, alive_c, info_c = fn(stack.init_state(), payload, length)
    st_r, q_r, ql_r, alive_r, info_r = ref_udp_rx_tx(
        apps_r, ref_udp_init_state(apps_r), payload, length)

    np.testing.assert_array_equal(np.asarray(q_c), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(ql_c), np.asarray(ql_r))
    np.testing.assert_array_equal(np.asarray(alive_c), np.asarray(alive_r))
    assert_trees_equal(info_c, info_r)
    assert_trees_equal(
        {k: st_c[k] for k in ("dispatch", "apps", "rx_count")},
        {k: st_r[k] for k in ("dispatch", "apps", "rx_count")})


def test_udp_compiled_matches_over_multiple_batches():
    """Dispatch state (round-robin counters) must stay in lockstep."""
    apps_c, apps_r = make_apps(), make_apps()
    stack = UdpStack(apps_c, IP_S, with_telemetry=False)
    payload, length = golden_udp_batch()
    st_c, st_r = stack.init_state(), ref_udp_init_state(apps_r)
    for _ in range(3):
        st_c, q_c, ql_c, *_ = stack.rx_tx(st_c, payload, length)
        st_r, q_r, ql_r, *_ = ref_udp_rx_tx(apps_r, st_r, payload, length)
        np.testing.assert_array_equal(np.asarray(q_c), np.asarray(q_r))
    assert_trees_equal(st_c["dispatch"], st_r["dispatch"])


# ---------------------------------------------------------------------------
# TCP equivalence (plain and NAT-inserted) incl. the TX build chain


def _tcp_golden_frames(dst_ip):
    syn = F.tcp_eth_frame(IP_C, dst_ip, 4000, 80, seq=900, ack=0,
                          flags=tcp.SYN)
    return [syn]


@pytest.mark.parametrize("with_nat", [False, True], ids=["plain", "nat"])
def test_tcp_compiled_matches_handwritten(with_nat):
    dst = VIP if with_nat else IP_S
    entries = [(VIP, IP_S)] if with_nat else None
    stack = TcpStack(IP_S, with_nat=with_nat, nat_entries=entries,
                     with_telemetry=False)
    st_c = stack.init_state()
    st_r = {"conn": tcp.init(16, local_ip=IP_S)}
    if with_nat:
        st_r["nat"] = nat_mod.init(entries)

    def both(st_c, st_r, frame):
        payload, length = F.to_batch([frame], 256)
        p, l = jnp.asarray(payload), jnp.asarray(length)
        st_c, resps_c = stack.rx(st_c, p, l)
        st_r, resps_r = ref_tcp_rx(st_r, p, l, with_nat)
        assert_trees_equal(resps_c, resps_r)
        return st_c, st_r, resps_c

    st_c, st_r, r = both(st_c, st_r, F.tcp_eth_frame(
        IP_C, dst, 4000, 80, seq=900, ack=0, flags=tcp.SYN))
    iss = int(r["tcp_seq"][0])
    st_c, st_r, _ = both(st_c, st_r, F.tcp_eth_frame(
        IP_C, dst, 4000, 80, seq=901, ack=iss + 1, flags=tcp.ACK))
    st_c, st_r, _ = both(st_c, st_r, F.tcp_eth_frame(
        IP_C, dst, 4000, 80, seq=901, ack=iss + 1,
        flags=tcp.ACK | tcp.PSH, payload=b"hello tcp"))
    np.testing.assert_array_equal(np.asarray(st_c["conn"]["rcv_nxt"]),
                                  np.asarray(st_r["conn"]["rcv_nxt"]))

    # TX path: engine emits a segment; both build chains must agree bit
    # for bit (the compiled chain builds with the physical source and lets
    # NAT patch the checksum incrementally — RFC 1624 — so the results
    # must still be identical)
    conn, ok = tcp.app_send(st_c["conn"], 0,
                            jnp.asarray(list(b"reply-bytes"), jnp.uint8), 11)
    assert bool(ok)
    st_c["conn"] = conn
    st_r["conn"] = conn
    conn, seg, data, dlen = tcp.tx_emit(conn, 0, mss=64)
    assert bool(seg["emit"])
    seg_meta = {k: v for k, v in seg.items() if k != "emit"}
    q_c, ql_c = stack.tx_frame(st_c, seg_meta, data, dlen)
    q_r, ql_r = ref_tcp_tx_frame(st_r, seg_meta, data, dlen, with_nat)
    np.testing.assert_array_equal(np.asarray(q_c), np.asarray(q_r))
    np.testing.assert_array_equal(np.asarray(ql_c), np.asarray(ql_r))
    if with_nat:
        # and the client-visible source really is the virtual IP with a
        # checksum valid for it
        _, _, m2, ok_ip = ipv4.parse(q_c, ql_c)
        assert bool(ok_ip[0]) and int(m2["src_ip"][0]) == VIP


# ---------------------------------------------------------------------------
# flexibility: NAT inserted into the *UDP* stack purely by topology edit


def test_nat_tile_added_to_udp_topology_only():
    """paper Table 1: adding a tile touches configuration, not code.  The
    NAT tile lands between ip_rx and udp_rx via insert_on_path; no tile
    function changes, and the stack keeps serving — now on a virtual IP."""
    apps = [echo.make(port=7, n_replicas=2)]
    topo = udp_topology(apps)
    # re-place the downstream tiles one column right to open a slot at
    # (2, 0) — pure config edits; a detour placement would re-acquire the
    # (2,0)->(3,0) channel and the deadlock analysis (rightly) rejects it
    topo.dim_x += 1
    for nm in ("udp_rx", "echo.0", "echo.1"):
        topo.tile(nm).x += 1
    topo.insert_on_path("nat_rx", "nat_rx", 2, 0, "ip_rx", "udp_rx")
    stack = UdpStack(apps, IP_S, topo=topo, nat_entries=[(VIP, IP_S)])
    state = stack.init_state()

    fr = F.udp_rpc_frame(IP_C, VIP, 5000, 7,        # client talks to the VIP
                         rpc.np_frame(rpc.MSG_ECHO, 9, b"via-nat"))
    payload, length = F.to_batch([fr], 256)
    state, q, ql, alive, info = stack.rx_tx(
        state, jnp.asarray(payload), jnp.asarray(length))
    # UDP checksum still verifies after translation (incremental fixup)
    assert bool(alive[0]) and bool(info["echo"][0])
    # the reply's source is the *physical* address the VIP resolved to
    p, l, m = eth.parse(q, ql)
    p, l, m2, ok_ip = ipv4.parse(p, l)
    assert bool(ok_ip[0]) and int(m2["src_ip"][0]) == IP_S
    # the executor really took the detour: nat_rx is in the compiled order
    assert "nat_rx" in stack.pipeline.order
    # and the same topology minus the edit does not know the VIP
    plain = UdpStack([echo.make(port=7, n_replicas=2)], IP_S)
    pstate = plain.init_state()
    _, _, _, alive_p, _ = plain.rx_tx(
        pstate, jnp.asarray(payload), jnp.asarray(length))
    assert bool(alive_p[0])        # parses fine...
    assert "nat_rx" not in plain.pipeline.order


def test_branch_inserted_alive_tile_does_not_clobber_siblings():
    """A NAT tile inserted on ONE app's branch must only judge packets
    routed through it — other apps' traffic keeps its trunk alive mask."""
    from repro.apps import reed_solomon
    apps = [echo.make(port=7), reed_solomon.make(port=9000, n_replicas=1)]
    topo = udp_topology(apps)
    topo.insert_on_path("nat_rx", "nat_rx", 3, 1, "udp_rx", "echo")
    stack = UdpStack(apps, IP_S, topo=topo, nat_entries=[(VIP, IP_S)],
                     check_deadlock=False)       # alive semantics under test
    state = stack.init_state()
    frames = [F.udp_rpc_frame(IP_C, IP_S, 5000, 9000,
                              rpc.np_frame(rpc.MSG_RS_ENCODE, 1, bytes(4096))),
              F.udp_rpc_frame(IP_C, IP_S, 5001, 7,
                              rpc.np_frame(rpc.MSG_ECHO, 2, b"hi"))]
    payload, length = F.to_batch(frames, 4400)
    state, q, ql, alive, info = stack.rx_tx(
        state, jnp.asarray(payload), jnp.asarray(length))
    assert bool(alive[0])            # rs packet survives the echo-side NAT
    assert bool(alive[1]) and bool(info["echo"][1])
    assert bool(info["rs"][0])


def test_udp_checksum_fixup_never_emits_zero():
    """RFC 768: 0 means 'no checksum' — an incremental fixup landing on 0
    must emit 0xFFFF like a full recompute (udp.build) would."""
    from repro.net import bytesops as B
    payload = jnp.zeros((1, 64), jnp.uint8)
    payload = B.set_be16(payload, 6, jnp.asarray([0x0001], jnp.uint32))
    old = jnp.zeros((1,), jnp.uint32)
    new = jnp.asarray([0x00010000], jnp.uint32)   # delta folds sum to 0xFFFF
    out = nat_mod.fixup_l4_checksum(payload, 6, old, new,
                                    jnp.ones((1,), bool))
    got = int(B.be16(out, 6)[0])
    assert got == 0xFFFF             # not 0 (would disable verification)
    # and the patched value still verifies as a one's-complement sum:
    # ~(~0x0001 + ~0 + ~0 + 1 + 0) folds to 0xFFFF == -0, i.e. valid


def test_compiled_order_follows_routes_not_code():
    """The executor's stage order is derived from the route DAG."""
    stack = UdpStack([echo.make(port=7)], IP_S)
    order = stack.pipeline.order
    assert order.index("eth_rx") < order.index("ip_rx") < \
        order.index("udp_rx") < order.index("echo") < \
        order.index("udp_tx") < order.index("ip_tx") < order.index("eth_tx")
    t = tcp_topology(with_nat=True)
    tcp_stack = TcpStack(IP_S, with_nat=True, nat_entries=[(VIP, IP_S)])
    assert tcp_stack.rx_pipe.order == ["eth_rx", "ip_rx", "nat_rx", "tcp_rx"]
    assert tcp_stack.tx_pipe.order == ["tcp_tx", "nat_tx", "ip_tx"]
    assert t.validate() == []


# ---------------------------------------------------------------------------
# telemetry: per-tile counters on every path + RingLog wraparound


def test_per_tile_telemetry_counters():
    stack = UdpStack([echo.make(port=7, n_replicas=2)], IP_S)
    state = stack.init_state()
    frames = [F.udp_rpc_frame(IP_C, IP_S, 5000 + i, 7,
                              rpc.np_frame(rpc.MSG_ECHO, i, b"x"))
              for i in range(3)]
    frames.append(F.udp_rpc_frame(IP_C, IP_S, 5009, 4444,     # unknown port
                                  rpc.np_frame(rpc.MSG_ECHO, 9, b"y")))
    corrupt = bytearray(frames[0])
    corrupt[20] ^= 0xFF                                        # IP checksum
    frames.append(bytes(corrupt))
    payload, length = F.to_batch(frames, 256)
    payload, length = jnp.asarray(payload), jnp.asarray(length)
    state, *_ = jax.jit(stack.rx_tx)(state, payload, length)
    logs = stack.pipeline.node_logs(state)
    assert set(logs) == set(stack.pipeline.order)
    row_eth = np.asarray(telemetry.latest(logs["eth_rx"])[0])
    row_ip = np.asarray(telemetry.latest(logs["ip_rx"])[0])
    row_app = np.asarray(telemetry.latest(logs["echo"])[0])
    assert row_eth[1] == 5 and row_eth[2] == 0   # whole batch at ingress
    assert row_ip[1] == 5 and row_ip[2] == 1     # corrupt checksum dropped
    assert row_app[1] == 3                       # echo-port packets only
    # NoC latency estimates grow along the chain and are non-trivial
    assert 0 < row_eth[3] < row_ip[3] < row_app[3]
    assert int(state["telemetry"]["step"]) == 1


def test_ringlog_wraparound():
    log = telemetry.make_log(4)
    for i in range(6):               # 6 single-row writes into 4 slots
        row = telemetry.counter_row(jnp.int32(i), i, 0, 0, 0)
        log = telemetry.append(log, row, jnp.ones((1,), bool))
    assert int(log.wr) == 6
    ents = np.asarray(log.entries)
    # slots hold the last writes modulo capacity: 4,5 overwrote 0,1
    np.testing.assert_array_equal(ents[:, 0].tolist(), [4, 5, 2, 3])
    # latest() serves entries in age order across the wrap
    np.testing.assert_array_equal(
        np.asarray(telemetry.latest(log, 4))[:, 0].tolist(), [2, 3, 4, 5])
    # masked (parked) writes consume no slots
    before = np.asarray(log.entries).copy()
    log2 = telemetry.append(log, telemetry.counter_row(
        jnp.int32(9), 9, 9, 9, 9), jnp.zeros((1,), bool))
    assert int(log2.wr) == 6
    np.testing.assert_array_equal(np.asarray(log2.entries), before)
