"""Network stack: golden-frame interop (Linux wire format), checksums,
TCP engine behaviour, NAT, RPC framing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.net import bytesops as B
from repro.net import eth, frames as F, ipinip, ipv4, nat, rpc, tcp, udp

IP_A = F.ip("10.0.0.2")     # client
IP_S = F.ip("10.0.0.1")     # server/accelerator


def rx_udp(frames_list, max_len=512):
    payload, length = F.to_batch(frames_list, max_len)
    p, l = jnp.asarray(payload), jnp.asarray(length)
    p, l, m = eth.parse(p, l)
    p, l, m2, ok_ip = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok_udp = udp.parse(p, l, m)
    return p, l, m3, ok_ip & ok_udp


# ---------------------------------------------------------------------------
# UDP path


def test_udp_rx_parses_golden_frame():
    fr = F.udp_rpc_frame(IP_A, IP_S, 5555, 9000, b"hello")
    p, l, m, ok = rx_udp([fr])
    assert bool(ok[0])
    assert int(m["src_port"][0]) == 5555 and int(m["dst_port"][0]) == 9000
    assert bytes(p[0, :l[0]].tolist()) == b"hello"


def test_udp_vlan_tagged():
    fr = F.udp_rpc_frame(IP_A, IP_S, 5555, 9000, b"v", vlan=7)
    p, l, m, ok = rx_udp([fr])
    assert bool(ok[0]) and int(l[0]) == 1


def test_corrupted_ip_checksum_dropped():
    fr = bytearray(F.udp_rpc_frame(IP_A, IP_S, 5555, 9000, b"x"))
    fr[20] ^= 0xFF          # corrupt an IP header byte
    p, l, m, ok = rx_udp([bytes(fr)])
    assert not bool(ok[0])


def test_udp_tx_roundtrip_checksum_valid():
    fr = F.udp_rpc_frame(IP_A, IP_S, 5555, 9000, b"ping!")
    p, l, m, ok = rx_udp([fr])
    # build reply (swap all fields)
    m_tx = dict(m)
    m_tx["src_ip"], m_tx["dst_ip"] = m["dst_ip"], m["src_ip"]
    m_tx["src_port"], m_tx["dst_port"] = m["dst_port"], m["src_port"]
    m_tx["ip_proto"] = jnp.full_like(m["src_ip"], 17)
    q, ql = udp.build(p, l, m_tx)
    q, ql = ipv4.build(q, ql, m_tx)
    m_tx["eth_dst_hi"], m_tx["eth_dst_lo"] = m["eth_src_hi"], m["eth_src_lo"]
    m_tx["eth_src_hi"], m_tx["eth_src_lo"] = m["eth_dst_hi"], m["eth_dst_lo"]
    m_tx["ethertype"] = m["ethertype"]
    q, ql = eth.build(q, ql, m_tx)
    # a Linux client would now parse this: verify via our own parser
    q2, l2, m2 = eth.parse(q, ql)
    q3, l3, m3, ok_ip = ipv4.parse(q2, l2)
    m2.update(m3)
    q4, l4, m4, ok_udp = udp.parse(q3, l3, m2)
    assert bool(ok_ip[0]) and bool(ok_udp[0])
    assert bytes(q4[0, :l4[0]].tolist()) == b"ping!"


def test_checksum_against_numpy_oracle():
    rng = np.random.default_rng(0)
    for n in (1, 2, 19, 64, 333):
        data = rng.integers(0, 256, (2, 512), dtype=np.uint8)
        got = B.checksum16(jnp.asarray(data), 0,
                           jnp.asarray([n, n], jnp.int32))
        want = B.np_checksum16(bytes(data[0, :n].tobytes()))
        assert int(got[0]) == want


# ---------------------------------------------------------------------------
# TCP engine


def tcp_rx_frames(conn, frames_list, max_len=600):
    payload, length = F.to_batch(frames_list, max_len)
    p, l = jnp.asarray(payload), jnp.asarray(length)
    p, l, m = eth.parse(p, l)
    p, l, m2, ok = ipv4.parse(p, l)
    m.update(m2)
    data, dlen, m = tcp.parse_segment(p, l, m)
    return tcp.rx_batch(conn, data, dlen, m)


def test_tcp_handshake_and_data():
    conn = tcp.init(local_ip=IP_S)
    syn = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=1000, ack=0,
                          flags=tcp.SYN)
    conn, resps = tcp_rx_frames(conn, [syn])
    assert bool(resps["emit"][0])
    assert int(resps["tcp_flags"][0]) == tcp.SYN | tcp.ACK
    assert int(resps["tcp_ack"][0]) == 1001
    iss = int(resps["tcp_seq"][0])

    ack = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=1001, ack=iss + 1,
                          flags=tcp.ACK)
    data = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=1001, ack=iss + 1,
                           flags=tcp.ACK | tcp.PSH, payload=b"GET /stats")
    conn, resps = tcp_rx_frames(conn, [ack, data])
    assert int(conn["accepts"]) == 1
    assert int(conn["state"][0]) == tcp.ESTABLISHED
    # data ACKed
    assert bool(resps["emit"][1])
    assert int(resps["tcp_ack"][1]) == 1001 + len(b"GET /stats")
    # app can read it (request/notify interface)
    assert bool(tcp.app_readable(conn, 0, 10))
    conn, rdata, ok = tcp.app_read(conn, 0, 10)
    assert bool(ok) and bytes(rdata.tolist()) == b"GET /stats"


def _establish(conn, sport=4000, seq0=5000):
    syn = F.tcp_eth_frame(IP_A, IP_S, sport, 80, seq=seq0, ack=0,
                          flags=tcp.SYN)
    conn, r = tcp_rx_frames(conn, [syn])
    iss = int(r["tcp_seq"][0])
    ack = F.tcp_eth_frame(IP_A, IP_S, sport, 80, seq=seq0 + 1, ack=iss + 1,
                          flags=tcp.ACK)
    conn, _ = tcp_rx_frames(conn, [ack])
    return conn, iss


def test_tcp_tx_and_fast_retransmit():
    conn = tcp.init(local_ip=IP_S)
    conn, iss = _establish(conn)
    conn, ok = tcp.app_send(conn, 0, jnp.asarray(list(b"response-bytes"),
                                                 jnp.uint8), 14)
    assert bool(ok)
    conn, seg, data, dlen = tcp.tx_emit(conn, 0, mss=8)
    assert bool(seg["emit"]) and int(dlen) == 8
    assert bytes(data[:8].tolist()) == b"response"
    assert int(seg["tcp_seq"]) == (iss + 1) & 0xFFFFFFFF
    conn, seg2, data2, dlen2 = tcp.tx_emit(conn, 0, mss=8)
    assert int(dlen2) == 6 and bytes(data2[:6].tolist()) == b"-bytes"

    # 3 duplicate ACKs at snd_una -> fast retransmit
    dup = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=5001, ack=iss + 1,
                          flags=tcp.ACK)
    conn, resps = tcp_rx_frames(conn, [dup, dup, dup])
    assert bool(resps["fast_retx"][2])
    conn, seg3, data3, dlen3 = tcp.tx_emit(conn, 0, mss=8, retransmit=True)
    assert int(seg3["tcp_seq"]) == (iss + 1) & 0xFFFFFFFF  # resend from una
    assert bytes(data3[:8].tolist()) == b"response"


def test_tcp_flow_control_window():
    conn = tcp.init(local_ip=IP_S)
    conn, iss = _establish(conn)
    # peer advertises a 4-byte window
    wnd = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=5001, ack=iss + 1,
                          flags=tcp.ACK, window=4)
    conn, _ = tcp_rx_frames(conn, [wnd])
    conn, ok = tcp.app_send(conn, 0,
                            jnp.asarray(list(b"0123456789"), jnp.uint8), 10)
    conn, seg, data, dlen = tcp.tx_emit(conn, 0, mss=8)
    assert int(dlen) == 4          # window-limited
    conn, seg2, data2, dlen2 = tcp.tx_emit(conn, 0, mss=8)
    assert int(dlen2) == 0         # window exhausted until ACK


def test_tcp_out_of_order_dropped_and_dup_acked():
    conn = tcp.init(local_ip=IP_S)
    conn, iss = _establish(conn)
    ooo = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=5010, ack=iss + 1,
                          flags=tcp.ACK | tcp.PSH, payload=b"late")
    conn, resps = tcp_rx_frames(conn, [ooo])
    assert bool(resps["emit"][0])
    assert int(resps["tcp_ack"][0]) == 5001      # dup ack at rcv_nxt
    assert not bool(tcp.app_readable(conn, 0, 1))


def test_tcp_timer_retransmit():
    conn = tcp.init(local_ip=IP_S)
    conn, iss = _establish(conn)
    conn, _ = tcp.app_send(conn, 0, jnp.asarray(list(b"abcd"), jnp.uint8), 4)
    conn, seg, _, _ = tcp.tx_emit(conn, 0, mss=8)
    assert int(conn["snd_nxt"][0]) == (iss + 5) & 0xFFFFFFFF
    for _ in range(8):
        conn, expired = tcp.tick(conn, timeout=8)
    assert bool(expired[0])
    assert int(conn["snd_nxt"][0]) == (iss + 1) & 0xFFFFFFFF  # go-back-N


def test_tcp_migration_serialize_reinstall():
    conn_a = tcp.init(local_ip=IP_S)
    conn_a, iss = _establish(conn_a)
    data = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=5001, ack=iss + 1,
                           flags=tcp.ACK | tcp.PSH, payload=b"state!")
    conn_a, _ = tcp_rx_frames(conn_a, [data])
    blob = tcp.serialize_conn(conn_a, 0)
    # reinstall on a different engine (the migration target)
    conn_b = tcp.init(local_ip=IP_S)
    conn_b = tcp.install_conn(conn_b, 3, blob)
    assert int(conn_b["state"][3]) == tcp.ESTABLISHED
    # connection continues: next in-order segment is accepted seamlessly
    more = F.tcp_eth_frame(IP_A, IP_S, 4000, 80, seq=5007, ack=iss + 1,
                           flags=tcp.ACK | tcp.PSH, payload=b"more")
    conn_b, resps = tcp_rx_frames(conn_b, [more])
    assert int(resps["tcp_ack"][0]) == 5011
    conn_b, rdata, ok = tcp.app_read(conn_b, 3, 10)
    assert bool(ok) and bytes(rdata.tolist()) == b"state!more"


# ---------------------------------------------------------------------------
# NAT + IPinIP + RPC


def test_nat_rx_tx_translation():
    table = nat.init([(F.ip("20.0.0.9"), IP_S)])   # virtual -> physical
    meta = {"dst_ip": jnp.asarray([F.ip("20.0.0.9")], jnp.uint32),
            "src_ip": jnp.asarray([IP_S], jnp.uint32)}
    m2, found = nat.rx(table, meta)
    assert bool(found[0]) and int(m2["dst_ip"][0]) == IP_S
    m3, found2 = nat.tx(table, meta)
    assert bool(found2[0]) and int(m3["src_ip"][0]) == F.ip("20.0.0.9")


def test_nat_control_plane_migration_rewrite():
    table = nat.init([(F.ip("20.0.0.9"), IP_S)])
    table = nat.update(table, 0, F.ip("20.0.0.9"), F.ip("10.0.0.7"))
    meta = {"dst_ip": jnp.asarray([F.ip("20.0.0.9")], jnp.uint32)}
    m2, found = nat.rx(table, meta)
    assert int(m2["dst_ip"][0]) == F.ip("10.0.0.7")


def test_ipinip_encap_roundtrip():
    inner = F.ipv4_packet(IP_A, IP_S, 17, b"payload")
    p, l = F.to_batch([inner], 256)
    p, l = jnp.asarray(p), jnp.asarray(l)
    meta = {"src_ip": jnp.asarray([IP_A], jnp.uint32),
            "dst_ip": jnp.asarray([IP_S], jnp.uint32)}
    q, ql = ipinip.encap(p, l, meta, F.ip("1.1.1.1"), F.ip("2.2.2.2"))
    # outer parse
    q2, l2, m2, ok = ipv4.parse(q, ql)
    assert bool(ok[0]) and int(m2["ip_proto"][0]) == ipinip.PROTO_IPIP
    inner2, il, ok2 = ipinip.decap(q2, l2, m2)
    # inner parses as the original packet
    q3, l3, m3, ok3 = ipv4.parse(inner2, il)
    assert bool(ok3[0]) and int(m3["src_ip"][0]) == IP_A


def test_rpc_frame_roundtrip():
    fr = rpc.np_frame(rpc.MSG_ECHO, 77, b"abc")
    p, l = F.to_batch([fr], 64)
    body, blen, meta, ok = rpc.parse(jnp.asarray(p), jnp.asarray(l))
    assert bool(ok[0]) and int(meta["req_id"][0]) == 77
    assert bytes(body[0, :blen[0]].tolist()) == b"abc"
    out, olen = rpc.build(body, blen, rpc.MSG_ECHO,
                          meta["req_id"])
    body2, blen2, meta2, ok2 = rpc.parse(out, olen)
    assert bool(ok2[0]) and bytes(body2[0, :blen2[0]].tolist()) == b"abc"
