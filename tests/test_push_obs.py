"""Push-mode observability: postcards, series ring, SLO watchdog.

Acceptance coverage:
  * under a netem Gilbert-Elliott burst, the watchdog emits exactly one
    MSG_ALERT edge per burst, in the same batch the drop-rate window
    crosses the threshold (hysteresis: no storm, re-arm after clear);
  * postcards decode to per-hop paths consistent with the flight
    recorder's trace rows, and obey the runtime sampling knobs;
  * the series ring serves per-window deltas (incl. wraparound) over
    OP_SERIES_READ, and OP_SLO_SET installs rules live;
  * the scanned region stays free of host callbacks with postcards +
    series + watchdog enabled;
  * the mirror's extra egress frames and the watchdog's alert path are
    deadlock-analyzed (data + ctrl NoCs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo
from repro.core import control, deadlock
from repro.mgmt.console import MgmtConsole
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, udp_topology
from repro.netem.link import GilbertElliott, Link, LinkConfig
from repro.obs import collector, export, postcard, prom, reasons, series, slo

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
MGMT = 9909
APP_PORT = 7


def echo_frame(sport, req=1, payload=b"x"):
    return F.udp_rpc_frame(IP_C, IP_S, sport, APP_PORT,
                           rpc.np_frame(rpc.MSG_ECHO, req, payload))


def ip_corrupt(frame):
    fr = bytearray(frame)
    fr[F.l2_offset(frame) + 10] ^= 0xFF         # IP header checksum
    return bytes(fr)


def make_push_stack():
    apps = [echo.make(port=APP_PORT)]
    topo = udp_topology(apps)
    postcard.bind_mirror(topo, collector_ip=IP_C)
    slo.bind_watchdog(topo, collector_ip=IP_C)
    return UdpStack(apps, IP_S, topo=topo, mgmt_port=MGMT)


@pytest.fixture(scope="module")
def push_stack():
    return make_push_stack()


def stream(stack, state, batches, batch=4, width=256):
    arena = F.FrameArena(len(batches), batch, width)
    arena.fill([f for b in batches for f in b])
    return stack.stream_fn()(state, jnp.asarray(arena.payload),
                             jnp.asarray(arena.length))


def arm(stack, state, *, shift=0, window=1, rules=()):
    """Enable the recorder, set the window length, install rules; leaves
    the staleness batches behind so the next stream starts clean."""
    con = MgmtConsole(stack)
    state, r = con.set_trace(state, True, shift=shift)
    assert r["status"] == 1
    state, r = con.set_window(state, window)
    assert r["status"] == 1
    for (slot, metric, node, raise_thr, clear_thr) in rules:
        state, r = con.set_slo(state, slot, metric, node, raise_thr,
                               clear_thr)
        assert r["status"] == 1
    return con, state


# ---------------------------------------------------------------------------
# series ring (host-level unit + device readback)


def test_series_window_deltas_and_ring_wraparound():
    ser = series.make_series(2, windows=4)
    ser["win_len"] = jnp.asarray(1, jnp.int32)
    histo = jnp.zeros((3, 16), jnp.int32)
    for k in range(6):
        frames = jnp.asarray([k + 1, 1], jnp.int32)     # cumulative adds
        histo = histo.at[0, k % 16].add(1)
        ser = series.update(ser, frames, jnp.zeros(2, jnp.int32),
                            frames * 10, jnp.full((2,), 5 * (k + 1),
                                                  jnp.int32), histo)
    rows = series.series_rows(ser)
    # 6 windows closed into a 4-deep ring: only the last 4 survive
    assert int(ser["wr"]) == 6
    assert [w for w, _ in rows] == [2, 3, 4, 5]
    last_w, last = series.last_window(ser)
    assert last_w == 5
    # per-window deltas, not totals: window k saw exactly its own adds
    assert last[0, series.M_FRAMES] == 6 and last[1, series.M_FRAMES] == 1
    assert last[0, series.M_BYTES] == 60
    # retx arrives cumulative; the delta falls out of cum-prev
    assert last[0, series.M_RETX] == 5


def test_p99_bucket_picks_the_right_bucket():
    h = jnp.zeros((2, 16), jnp.int32).at[0, 3].set(99).at[0, 7].set(1)
    b = np.asarray(series.p99_bucket(h))
    assert b[0] == 3            # 99% of mass is at bucket 3
    assert b[1] == 0            # empty row -> 0


def test_series_read_over_mgmt(push_stack):
    stack = push_stack
    con, state = arm(stack, stack.init_state(), window=1)
    batches = [[echo_frame(5000 + i) for i in range(4)] for _ in range(2)]
    state, _ = stream(stack, state, batches)
    # age 0 = newest completed window = the 2nd stream batch (the mgmt
    # batches from arm() merged into an earlier window: win_len was
    # still the default while they ran)
    state, r = con.read_series(state, "udp_rx", age=0)
    s = r["series"]
    assert r["status"] == 2 + series.NUM_METRICS
    assert s["win_len"] == 1
    assert s["frames"] == 4 and s["drops"] == 0 and s["bytes"] > 0
    # invalid window age: served=0, no decode
    state, r = con.read_series(state, "udp_rx", age=1000)
    assert r["status"] == 0 and "series" not in r


# ---------------------------------------------------------------------------
# watchdog: GE burst -> exactly one edge, hysteresis, live rules


def _hysteresis_reference(drop_counts, raise_thr, clear_thr):
    """Python model of the device rule: per-window edge list."""
    edges, active = [], False
    for w, d in enumerate(drop_counts):
        if not active and d >= raise_thr:
            active = True
            edges.append(w)
        elif active and d <= clear_thr:
            active = False
    return edges


def test_watchdog_ge_burst_single_edge(push_stack):
    """Drive the stack through a Gilbert-Elliott loss schedule: frames
    the netem chain marks lost arrive corrupted, so ip_rx attributes an
    IP_CSUM drop.  The device watchdog must alert exactly once per
    burst, in the same batch the drop-rate window crosses."""
    stack = push_stack
    n_batches, batch = 12, 4
    link = Link(LinkConfig(gilbert=GilbertElliott(
        p_good_bad=0.2, p_bad_good=0.4), seed=11))
    sched = [[link._drop() for _ in range(batch)] for _ in range(n_batches)]
    drop_counts = [sum(b) for b in sched]
    edges = _hysteresis_reference(drop_counts, raise_thr=2, clear_thr=0)
    assert edges, "seed must produce at least one burst"

    con, state = arm(stack, stack.init_state(), window=1,
                     rules=[(0, "drops", "ip_rx", 2, 0)])
    batches = [[ip_corrupt(echo_frame(5000 + j)) if sched[b][j]
                else echo_frame(5000 + j) for j in range(batch)]
               for b in range(n_batches)]
    state, outs = stream(stack, state, batches)

    av = np.asarray(outs["alert_valid"])[:, 0]
    got = [int(b) for b in np.flatnonzero(av)]
    # exactly one edge per burst, each in the batch whose window crossed
    assert got == edges
    assert int(state["slo"]["alerts"]) == len(edges)

    alerts = [collector.decode_alert(f) for f in collector.harvest(
        outs["alert_payload"], outs["alert_len"], outs["alert_valid"])]
    assert len(alerts) == len(edges)
    a = alerts[0]
    assert a["metric"] == "drops"
    assert a["node"] == stack.pipeline.order.index("ip_rx")
    assert a["value"] == drop_counts[edges[0]]
    assert a["threshold"] == 2


def test_watchdog_hysteresis_rearm(push_stack):
    """A sustained burst is ONE alert; after the rate clears, the next
    burst re-arms and fires a second edge."""
    stack = push_stack
    good = [echo_frame(6000 + i) for i in range(4)]
    bad = [ip_corrupt(f) for f in good]
    con, state = arm(stack, stack.init_state(), window=1,
                     rules=[(0, "drops", "ip_rx", 3, 1)])
    batches = [good, bad, bad, bad, good, bad]
    state, outs = stream(stack, state, batches)
    av = np.asarray(outs["alert_valid"])[:, 0]
    assert list(np.flatnonzero(av)) == [1, 5]


def test_slo_set_validation_and_clear(push_stack):
    stack = push_stack
    con = MgmtConsole(stack)
    state = stack.init_state()
    state, r = con.set_slo(state, 99, "drops", "ip_rx", 2)   # bad slot
    assert r["status"] == 0
    state, r = con.set_slo(state, 1, "frames", "udp_rx", 100)
    assert r["status"] == 1
    state, r = con.clear_slo(state, 1)
    assert r["status"] == 1
    # two staleness batches later the table reflects the clear
    assert int(state["slo"]["enabled"][1]) == 0


# ---------------------------------------------------------------------------
# postcards: consistency with the flight recorder, sampling knobs


def test_postcards_match_flight_recorder(push_stack):
    stack = push_stack
    con, state = arm(stack, stack.init_state(), shift=0)
    frames = [echo_frame(5000 + i, req=i) for i in range(7)]
    frames.append(ip_corrupt(echo_frame(5007)))
    state, outs = stream(stack, state, [frames[:4], frames[4:]])

    cards = [collector.decode_postcard(f) for f in collector.harvest(
        outs["pc_payload"], outs["pc_len"], outs["pc_valid"])]
    assert len(cards) == 8 and all(c is not None for c in cards)

    by_fid = {row["frame_id"]: row
              for row in export.trace_rows(state["telemetry"]["obs"])}
    matched = 0
    for c in cards:
        row = by_fid.get(c["frame_id"])
        if row is None:
            continue                      # recorder ring may have wrapped
        matched += 1
        visited = [h["stage"] for h in c["hops"] if h["visited"]]
        assert visited == row["visited"]
        assert c["first_reason"] == row["drop_reason"]
        for h in c["hops"]:
            if h["visited"]:
                assert h["enter"] == row["enter"][h["stage"]]
                assert h["exit"] == row["exit"][h["stage"]]
    assert matched == 8
    # the corrupted frame's card says where and why it died
    dead = [c for c in cards if c["dropped"]]
    assert len(dead) == 1
    assert dead[0]["first_reason"] == reasons.IP_CSUM
    paths = collector.flow_paths(dead, stack.pipeline.order)
    (path_entries,) = paths.values()
    assert path_entries[0]["path"][-1] == "ip_rx"   # died at ip_rx
    assert path_entries[0]["first_reason"] == "ip_csum"


def test_postcards_obey_runtime_sampling(push_stack):
    stack = push_stack
    con, state = arm(stack, stack.init_state(), shift=2)   # 1 in 4
    fid0 = int(state["telemetry"]["obs"]["frame_ctr"])
    batches = [[echo_frame(5000 + i) for i in range(4)] for _ in range(2)]
    state, outs = stream(stack, state, batches)
    pv = np.asarray(outs["pc_valid"]).reshape(-1)
    fids = fid0 + np.arange(pv.size)
    assert (pv == ((fids & 3) == 0)).all()


def test_postcard_perfetto_merge(push_stack, tmp_path):
    stack = push_stack
    con, state = arm(stack, stack.init_state(), shift=0)
    state, outs = stream(stack, state,
                         [[echo_frame(5000 + i) for i in range(4)]])
    cards = [collector.decode_postcard(f) for f in collector.harvest(
        outs["pc_payload"], outs["pc_len"], outs["pc_valid"])]
    out = tmp_path / "merged.perfetto.json"
    n = collector.write_perfetto(str(out), cards, stack.pipeline.order,
                                 state=state, pipeline=stack.pipeline)
    import json
    ev = json.loads(out.read_text())["traceEvents"]
    assert len(ev) == n
    assert {e["pid"] for e in ev} == {0, 1}       # both halves present
    text = prom.render_state(state, stack.pipeline)
    assert "beehive_window_drops" in text and "beehive_slo_active" in text


# ---------------------------------------------------------------------------
# scanned region stays host-callback-free; NoC safety


def test_push_obs_adds_no_host_callbacks(push_stack):
    stack = push_stack
    con, state = arm(stack, stack.init_state(),
                     rules=[(0, "drops", "ip_rx", 2, 0)])
    arena = F.FrameArena(2, 2, 256)
    arena.fill([echo_frame(5000 + i) for i in range(4)])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    closed = jax.make_jaxpr(stack.run_stream)(state, p, l)
    prims = set()

    def walk(jaxpr):
        for eq in jaxpr.eqns:
            prims.add(eq.primitive.name)
            for v in eq.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax.core.ClosedJaxpr):
                        walk(s.jaxpr)
                    elif isinstance(s, jax.core.Jaxpr):
                        walk(s)

    walk(closed.jaxpr)
    assert "scan" in prims
    assert not prims & {"pure_callback", "io_callback", "debug_callback",
                        "infeed", "outfeed", "device_put"}


def test_mirror_and_alert_paths_are_deadlock_analyzed(push_stack):
    topo = push_stack.topo
    assert topo.has_tile("int_mirror") and topo.has_tile("watchdog")
    # the watchdog's in-band alert endpoint landed on the ctrl NoC
    assert topo.has_tile("watchdog.a")
    assert deadlock.analyze(topo, "data").ok
    assert deadlock.analyze(topo, "ctrl").ok
    # both taps are compiled, counted pipeline nodes
    assert "int_mirror" in push_stack.pipeline.order
    assert "watchdog" in push_stack.pipeline.order


def test_push_taps_do_not_perturb_the_datapath(push_stack):
    """tx/alive outputs with mirror+watchdog bound are bit-identical to
    the plain stack's."""
    frames = [echo_frame(5000 + i) for i in range(3)] + \
        [ip_corrupt(echo_frame(5003))]
    plain = UdpStack([echo.make(port=APP_PORT)], IP_S, mgmt_port=MGMT)
    arena = F.FrameArena(1, 4, 256)
    arena.fill(frames)
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)
    _, o_push = push_stack.run_stream(push_stack.init_state(), p, l)
    _, o_plain = plain.run_stream(plain.init_state(), p, l)
    for k in ("tx_payload", "tx_len", "alive"):
        assert (np.asarray(o_push[k]) == np.asarray(o_plain[k])).all()
