"""Direct-attached application serving through the compiled stack.

Acceptance coverage for the serving tentpole:
  * `rpc_msg` dispatch: udp_rx routes on the RPC msg_type to app tiles
    declared in the topology like any protocol tile (runtime-rewritable
    CAM, unmatched types drop);
  * `rs_serve` parity vs the numpy RS oracle — accelerator compute in
    the reply path with no host round trip;
  * `lm_serve` inside `run_stream`: device-resident session/KV state in
    the scan carry produces the exact token stream of the host-driven
    `ServeEngine.generate`, one request per token;
  * malformed / unknown-session / duplicate requests get error replies
    (never raise) and only valid requests advance session state;
  * zero host transfers inside the compiled serve program (jaxpr + HLO,
    mirroring tests/test_stream.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import lm_server
from repro.configs.serve_smoke import serve_config
from repro.kernels.rs_encode import gf
from repro.kernels.rs_encode.ref import rs_encode_np
from repro.models import model
from repro.net import eth, frames as F, ipv4, rpc, udp
from repro.net.stack import UdpStack, rpc_serve_topology
from repro.serve.engine import ServeEngine

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
PORT = 9400


def serve_frame(msg, req_id, body, sport=5000):
    return F.udp_rpc_frame(IP_C, IP_S, sport, PORT,
                           rpc.np_frame(msg, req_id, body))


def parse_reply(q, ql, i):
    p, l, m = eth.parse(q, ql)
    p, l, m2, ok1 = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok2 = udp.parse(p, l, m)
    body, blen, rmeta, ok3 = rpc.parse(p, l)
    assert bool(ok1[i]) and bool(ok2[i]) and bool(ok3[i])
    return bytes(np.asarray(body[i, :blen[i]]).tobytes())


# ---------------------------------------------------------------------------
# rpc_msg dispatch + rs_serve (no model: fast lane)


def test_rs_serve_direct_dispatch_and_parity():
    stack = UdpStack([], IP_S, topo=rpc_serve_topology(
        [("rs", "rs_serve", rpc.MSG_RS_ENCODE)]))
    state = stack.init_state()
    # msg_type routing is a runtime-rewritable CAM like any keyed route
    assert "udp_rx:rpc_msg" in state["routes"]

    rng = np.random.default_rng(0)
    block = rng.integers(0, 256, 4096, dtype=np.uint8).tobytes()
    frames = [serve_frame(rpc.MSG_RS_ENCODE, 0, block),
              serve_frame(rpc.MSG_RS_ENCODE, 1, b"short"),   # runt request
              serve_frame(rpc.MSG_ECHO, 2, b"x")]            # unrouted type
    p, l = F.to_batch(frames, 4400)
    state, q, ql, alive, info = stack.rx_tx(state, jnp.asarray(p),
                                            jnp.asarray(l))
    assert bool(alive.all())
    served = np.asarray(info["rs"])
    assert served.tolist() == [True, False, False]   # runt + unrouted type

    parity = parse_reply(q, ql, 0)
    assert len(parity) == 1024
    data = np.frombuffer(block, np.uint8).reshape(8, 512)
    want = rs_encode_np(data, gf.generator_matrix(8, 2)).reshape(-1)
    np.testing.assert_array_equal(np.frombuffer(parity, np.uint8), want)

    assert parse_reply(q, ql, 1) == b""        # runt: empty error reply
    assert int(np.asarray(state["apps"]["rs"]["ops"])) == 1
    assert int(np.asarray(state["apps"]["rs"]["bytes"])) == 4096


# ---------------------------------------------------------------------------
# lm_serve: direct-attached decode inside run_stream (model: slow lane)


@pytest.fixture(scope="module")
def serve_setup():
    cfg = serve_config()
    params = model.init_params(cfg, jax.random.key(0))
    return cfg, params


def make_serve_stack(cfg, params, max_sessions=2, max_seq=32):
    lm = lm_server.make_tile(cfg, params, max_sessions=max_sessions,
                             max_seq=max_seq)
    stack = UdpStack([lm], IP_S, topo=rpc_serve_topology(
        [("lm", "lm_serve", rpc.MSG_LM_GENERATE)]))
    return stack


def lm_frame(session, req_id):
    return serve_frame(rpc.MSG_LM_GENERATE, req_id,
                       lm_server.encode_request(session, 1, []))


@pytest.mark.slow
def test_lm_serve_stream_matches_engine(serve_setup):
    """The tentpole equivalence: N single-request windows through
    `run_stream` (session KV in the scan carry, one decode per request)
    produce exactly `ServeEngine.generate(sid, N)`."""
    cfg, params = serve_setup
    eng = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    prompt = np.arange(1, 7, dtype=np.int32)
    sid = eng.new_session(prompt)
    ref = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    want = ref.generate(ref.new_session(prompt), 4)

    stack = make_serve_stack(cfg, params)
    state = stack.init_state()
    state["apps"]["lm"] = lm_server.adopt_engine(state["apps"]["lm"], eng,
                                                 {42: sid})
    arena = F.FrameArena(4, 1, 160)
    arena.fill([lm_frame(42, i) for i in range(4)])
    state, outs = stack.run_stream(state, jnp.asarray(arena.payload),
                                   jnp.asarray(arena.length))
    assert bool(np.asarray(outs["alive"]).all())
    got = []
    for i in range(4):
        reply = parse_reply(outs["tx_payload"][i], outs["tx_len"][i], 0)
        s, toks, ok = lm_server.decode_reply(reply)
        assert ok and s == 42 and lm_server.reply_error(reply) is None
        got += toks
    assert got == want
    assert int(np.asarray(state["apps"]["lm"]["served"])) == 4


@pytest.mark.slow
def test_lm_serve_error_replies_and_coalescing(serve_setup):
    """One batch mixing valid / duplicate / unknown-session / truncated
    requests: errors come back as sentinel replies (nothing raises, the
    batch stays alive) and only the valid session advances — once."""
    cfg, params = serve_setup
    eng = ServeEngine(cfg, params, max_sessions=2, max_seq=32)
    sid = eng.new_session(np.arange(1, 7, dtype=np.int32))

    stack = make_serve_stack(cfg, params)
    state = stack.init_state()
    state["apps"]["lm"] = lm_server.adopt_engine(state["apps"]["lm"], eng,
                                                 {42: sid})
    pos0 = int(np.asarray(state["apps"]["lm"]["pos"])[sid])

    frames = [lm_frame(42, 0),
              lm_frame(42, 1),                       # duplicate: coalesces
              lm_frame(777, 2),                      # unknown session
              serve_frame(rpc.MSG_LM_GENERATE, 3,    # truncated request
                          lm_server.encode_request(43, 1, [])[:4])]
    p, l = F.to_batch(frames, 160)
    state, q, ql, alive, info = stack.rx_tx(state, jnp.asarray(p),
                                            jnp.asarray(l))
    assert bool(alive.all())

    r0 = lm_server.decode_reply(parse_reply(q, ql, 0))
    r1 = lm_server.decode_reply(parse_reply(q, ql, 1))
    assert r0 == r1 and r0[2] and len(r0[1]) == 1    # same token, once
    assert lm_server.reply_error(parse_reply(q, ql, 2)) == \
        lm_server.ERR_NO_SESSION
    assert lm_server.reply_error(parse_reply(q, ql, 3)) == \
        lm_server.ERR_BAD_REQUEST

    st = state["apps"]["lm"]
    assert int(np.asarray(st["pos"])[sid]) == pos0 + 1   # advanced ONCE
    assert int(np.asarray(st["served"])) == 2            # both valid rows


@pytest.mark.slow
def test_serve_stream_zero_host_transfers(serve_setup):
    """The direct-attached acceptance bar: the compiled serve program —
    parse tiles, lm_serve decode, reply framing — contains no host
    callbacks or transfers inside the scanned region."""
    cfg, params = serve_setup
    stack = make_serve_stack(cfg, params)
    state = stack.init_state()
    arena = F.FrameArena(2, 1, 160)
    arena.fill([lm_frame(42, i) for i in range(2)])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    fn = lambda st, pp, ll: stack.run_stream(st, pp, ll)
    closed = jax.make_jaxpr(fn)(state, p, l)
    prims = set()

    def walk(jaxpr):
        for eq in jaxpr.eqns:
            prims.add(eq.primitive.name)
            for v in eq.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax.core.ClosedJaxpr):
                        walk(s.jaxpr)
                    elif isinstance(s, jax.core.Jaxpr):
                        walk(s)

    walk(closed.jaxpr)
    assert "scan" in prims
    assert not prims & {"pure_callback", "io_callback", "debug_callback",
                        "infeed", "outfeed", "device_put"}

    hlo = jax.jit(fn).lower(state, p, l).compile().as_text()
    low = hlo.lower()
    assert "infeed" not in low and "outfeed" not in low
    assert "send-to-host" not in low and "recv-from-host" not in low
    assert "while" in low
