"""Degrade gracefully when `hypothesis` is not installed.

Property-based tests import ``given / settings / st`` from here instead of
from hypothesis directly.  When hypothesis is available we re-export it
untouched.  When it is missing, a small deterministic fallback runs each
property over a fixed set of pseudo-random examples (seeded, so failures
reproduce) — the properties still execute and the suite stays green, it
just loses hypothesis's shrinking and adversarial generation.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import random

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _Strategies:
        """Deterministic stand-ins for the strategies the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def binary(min_size=0, max_size=64):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return bytes(rng.getrandbits(8) for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))

        @staticmethod
        def lists(elem, min_size=0, max_size=8):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elem.example(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _Strategies()

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(0xBEE5)
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES))
                for _ in range(n):
                    drawn = [s.example(rng) for s in strategies]
                    fn(*args, *drawn, **kwargs)
            # hide the property's drawn parameters from pytest's fixture
            # resolution (it would otherwise look for fixtures named after
            # them); the wrapper itself takes nothing
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
