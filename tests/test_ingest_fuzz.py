"""Ingest-path hardening: no malformed input may raise anywhere on the
RPC/UDP serving path.

Regression coverage for the PR's bugfix satellites:
  * runt UDP headers (udp_len < 8) are rejected AND the returned payload
    length is clamped non-negative (it used to go negative and poison
    every downstream length computation);
  * `decode_request` / `decode_reply` are bounds-checked (ok-flag
    convention mirroring rpc.parse) — truncated payloads used to raise
    ``struct.error``;
  * `LmServerApp` frees sessions: LRU eviction on slot exhaustion (or an
    ERR_NO_SLOT reply with eviction disabled — never a RuntimeError),
    plus explicit MSG_LM_RELEASE close;
  * fuzz properties (hypothesis when available, the deterministic
    `_hyp_compat` fallback otherwise): random and truncated bytes
    through the udp + rpc parse chain and the app codecs never raise,
    and truncated frames always parse as ok=False.
"""
import struct

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp_compat import given, settings, st

from repro.apps import lm_server
from repro.apps.lm_server import (ERR_BAD_REQUEST, ERR_NO_SESSION,
                                  ERR_NO_SLOT, LmServerApp, decode_reply,
                                  decode_request, encode_reply,
                                  encode_request, reply_error)
from repro.net import eth, frames as F, ipv4, rpc, udp

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")


def udp_meta(n):
    return {"src_ip": jnp.full((n,), IP_C, jnp.uint32),
            "dst_ip": jnp.full((n,), IP_S, jnp.uint32)}


def parse_chain(frames, max_len=160):
    """Full rx parse (eth -> ip -> udp -> rpc) over raw frame bytes;
    returns the conjunction of every ok flag plus the udp/rpc lengths."""
    p, l = F.to_batch(frames, max_len)
    p, l = jnp.asarray(p), jnp.asarray(l)
    p, l, m = eth.parse(p, l)
    p, l, m2, ok1 = ipv4.parse(p, l)
    m.update(m2)
    p, plen, m3, ok2 = udp.parse(p, l, m)
    body, blen, rmeta, ok3 = rpc.parse(p, plen)
    return np.asarray(ok1 & ok2 & ok3), np.asarray(plen), np.asarray(blen)


# ---------------------------------------------------------------------------
# runt UDP header (deterministic regression)


def test_udp_runt_header_rejected_and_clamped():
    """udp_len in [0, 8) is a runt header: ok must drop and the returned
    payload length must clamp to zero, never go negative."""
    body = b"abcd"
    dgrams = [struct.pack("!HHHH", 5000, 9400, ulen, 0) + body
              for ulen in range(0, 8)]             # checksum 0 = disabled
    dgrams.append(struct.pack("!HHHH", 5000, 9400, 8 + len(body), 0) + body)
    p, l = F.to_batch(dgrams, 32)
    n = len(dgrams)
    _, plen, _, ok = udp.parse(jnp.asarray(p), jnp.asarray(l), udp_meta(n))
    ok, plen = np.asarray(ok), np.asarray(plen)
    assert not ok[:8].any()                        # every runt rejected
    assert (plen >= 0).all()                       # clamped, not negative
    assert bool(ok[8]) and plen[8] == len(body)    # well-formed still parses


def test_udp_len_beyond_buffer_rejected():
    dg = struct.pack("!HHHH", 5000, 9400, 200, 0) + b"xy"
    p, l = F.to_batch([dg], 32)
    _, plen, _, ok = udp.parse(jnp.asarray(p), jnp.asarray(l), udp_meta(1))
    assert not bool(ok[0])


# ---------------------------------------------------------------------------
# fuzz: the frame parse chain never raises, truncation never parses ok


@settings(max_examples=30)
@given(st.binary(min_size=0, max_size=150))
def test_fuzz_random_bytes_never_raise(blob):
    ok, plen, blen = parse_chain([blob])
    assert (plen >= 0).all() and (blen >= 0).all()


@settings(max_examples=30)
@given(st.integers(min_value=0, max_value=60))
def test_fuzz_truncated_frame_parses_not_ok(cut):
    frame = F.udp_rpc_frame(IP_C, IP_S, 5000, 9400,
                            rpc.np_frame(rpc.MSG_LM_GENERATE, 1,
                                         encode_request(7, 2, [1, 2, 3])))
    cut = min(cut, len(frame) - 1)
    ok, plen, blen = parse_chain([frame, frame[:cut]])
    assert bool(ok[0])                             # intact frame parses
    assert not bool(ok[1])                         # any truncation: not ok
    assert (plen >= 0).all() and (blen >= 0).all()


# ---------------------------------------------------------------------------
# app codecs: bounds-checked, ok-flag convention (used to raise)


def test_truncated_request_and_reply_decode_not_ok():
    req = encode_request(7, 2, [5, 6, 7])
    for k in range(len(req)):
        _, _, _, ok = decode_request(req[:k])
        assert not ok
    assert decode_request(req) == (7, 2, [5, 6, 7], True)

    rep = encode_reply(7, [1, 2, 3])
    for k in range(len(rep)):
        _, _, ok = decode_reply(rep[:k])
        assert not ok
    assert decode_reply(rep) == (7, [1, 2, 3], True)


def test_error_reply_roundtrip():
    rep = lm_server.encode_error(9, ERR_NO_SESSION)
    assert decode_reply(rep) == (9, [], True)
    assert reply_error(rep) == ERR_NO_SESSION
    assert reply_error(encode_reply(9, [4])) is None


@settings(max_examples=30)
@given(st.binary(min_size=0, max_size=40))
def test_fuzz_codecs_never_raise(blob):
    decode_request(blob)
    decode_reply(blob)
    reply_error(blob)


# ---------------------------------------------------------------------------
# session lifecycle: eviction / release / exhaustion (host path)


class FakeEngine:
    """ServeEngine's session-slot surface without the model: generate()
    tags tokens with the slot id so tests can see who answered."""

    def __init__(self, max_sessions=2):
        self.M = max_sessions
        self.used = np.zeros((max_sessions,), bool)

    def has_free_slot(self):
        return bool((~self.used).any())

    def new_session(self, prompt_tokens):
        free = np.where(~self.used)[0]
        if not len(free):
            raise RuntimeError("no free session slots")
        sid = int(free[0])
        self.used[sid] = True
        return sid

    def release(self, sid):
        self.used[sid] = False

    def generate(self, sid, n):
        return [100 + sid] * n


def test_lru_eviction_on_slot_exhaustion():
    app = LmServerApp(FakeEngine(2))
    for s in (1, 2):
        assert reply_error(app.handle(encode_request(s, 1, [s]))) is None
    # session 1 is LRU -> a third client evicts it, not an error
    assert reply_error(app.handle(encode_request(3, 1, [3]))) is None
    assert set(app.session_map) == {2, 3}
    # touching 2 re-orders the LRU list: next eviction takes 3
    app.handle(encode_request(2, 1, []))
    app.handle(encode_request(4, 1, [4]))
    assert set(app.session_map) == {2, 4}
    # the evicted session's follow-up (no prompt) is an error reply
    assert reply_error(app.handle(encode_request(1, 1, []))) == \
        ERR_NO_SESSION


def test_no_evict_mode_returns_error_reply():
    app = LmServerApp(FakeEngine(1), evict=None)
    assert reply_error(app.handle(encode_request(1, 1, [1]))) is None
    reply = app.handle(encode_request(2, 1, [2]))   # full: reply, no raise
    assert reply_error(reply) == ERR_NO_SLOT
    assert set(app.session_map) == {1}


def test_release_frees_the_slot():
    app = LmServerApp(FakeEngine(1), evict=None)
    app.handle(encode_request(1, 1, [1]))
    rel = app.handle_release(lm_server.encode_release(1))
    assert decode_reply(rel) == (1, [], True)
    assert app.session_map == {} and app.engine.has_free_slot()
    assert reply_error(app.handle(encode_request(2, 1, [2]))) is None
    # releasing an unknown / already-closed session is an error reply
    assert reply_error(app.handle_release(lm_server.encode_release(1))) == \
        ERR_NO_SESSION
    assert reply_error(app.handle_release(b"\x01")) == ERR_BAD_REQUEST


def test_malformed_request_gets_error_reply():
    app = LmServerApp(FakeEngine(1))
    assert reply_error(app.handle(b"")) == ERR_BAD_REQUEST
    assert reply_error(app.handle(b"\x00\x00\x00\x07\x00")) == \
        ERR_BAD_REQUEST
    # header claims more prompt tokens than the payload carries
    trunc = encode_request(7, 1, [1, 2, 3])[:-2]
    assert reply_error(app.handle(trunc)) == ERR_BAD_REQUEST
    assert app.session_map == {}                   # nothing half-opened
