"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp_compat import given, settings, st

from repro.configs import get_smoke_config
from repro.core.noc import chain_channels, dor_path
from repro.core.routing import fnv1a
from repro.models import model
from repro.models.blocks import linear_recurrence
from repro.net import bytesops as B


# ---------------------------------------------------------------------------
# model invariants


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
@pytest.mark.slow
def test_causality_future_does_not_affect_past(seed):
    """Changing token t+1.. must not change logits at positions <= t."""
    cfg = get_smoke_config("internlm2-1.8b")
    params = model.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (1, 10)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 7:] = rng.integers(0, cfg.vocab, 3)
    la = model.forward(cfg, params, {"tokens": jnp.asarray(toks)})
    lb = model.forward(cfg, params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(la[0, :7]), np.asarray(lb[0, :7]),
                               atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
@pytest.mark.slow
def test_ssm_causality(seed):
    cfg = get_smoke_config("falcon-mamba-7b")
    params = model.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab, (1, 10)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % cfg.vocab
    la = model.forward(cfg, params, {"tokens": jnp.asarray(toks)})
    lb = model.forward(cfg, params, {"tokens": jnp.asarray(toks2)})
    np.testing.assert_allclose(np.asarray(la[0, :9]), np.asarray(lb[0, :9]),
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(1, 64), st.integers(1, 4), st.integers(16, 64))
@pytest.mark.slow
def test_linear_recurrence_matches_loop(S, B_, D):
    """Chunked associative scan == naive sequential recurrence."""
    key = jax.random.key(S * 131 + B_ * 7 + D)
    a = jax.random.uniform(key, (B_, S, D), minval=0.2, maxval=0.99)
    b = jax.random.normal(jax.random.fold_in(key, 1), (B_, S, D))
    h0 = jnp.zeros((B_, D))
    hs, hl = linear_recurrence(a, b, h0, chunk=16)
    h = h0
    want = []
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        want.append(h)
    want = jnp.stack(want, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(want), atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(want[:, -1]),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# stack invariants


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=0, max_size=300))
def test_checksum_detects_single_bit_flips(data):
    if len(data) == 0:
        return
    cs = B.np_checksum16(data)
    flipped = bytearray(data)
    flipped[0] ^= 0x01
    assert B.np_checksum16(bytes(flipped)) != cs


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 7), st.integers(0, 7), st.integers(0, 7),
       st.integers(0, 7))
def test_dor_path_length_is_manhattan(x1, y1, x2, y2):
    path = dor_path((x1, y1), (x2, y2))
    assert len(path) == abs(x1 - x2) + abs(y1 - y2)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 3)), min_size=2,
                max_size=6))
def test_chain_channels_are_contiguous(coords):
    chans = chain_channels(coords)
    for a, b in zip(chans, chans[1:]):
        assert a.dst == b.src or True  # hops across tiles restart at tile
    # stronger: every per-hop subpath is contiguous
    for s, d in zip(coords, coords[1:]):
        sub = dor_path(s, d)
        for a, b in zip(sub, sub[1:]):
            assert a.dst == b.src


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 2**32 - 1))
def test_flow_hash_deterministic_and_sensitive(a, b):
    fa = {k: jnp.asarray([a], jnp.uint32) for k in
          ("src_ip", "dst_ip", "src_port", "dst_port")}
    fb = {k: jnp.asarray([b], jnp.uint32) for k in
          ("src_ip", "dst_ip", "src_port", "dst_port")}
    ha = int(fnv1a(list(fa.values()))[0])
    ha2 = int(fnv1a(list(fa.values()))[0])
    hb = int(fnv1a(list(fb.values()))[0])
    assert ha == ha2
    if a != b:
        assert ha != hb or True   # collisions allowed; determinism is the law


# ---------------------------------------------------------------------------
# byte ops


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 60), st.integers(0, 20))
def test_shift_left_right_inverse(n_bytes, shift):
    rng = np.random.default_rng(n_bytes * 100 + shift)
    data = rng.integers(0, 256, (1, 64), dtype=np.uint8)
    x = jnp.asarray(data)
    rt = B.shift_left(B.shift_right(x, shift), shift)
    np.testing.assert_array_equal(np.asarray(rt[0, :64 - shift]),
                                  data[0, :64 - shift])
