"""Network emulation: the deterministic link, and the compiled TCP stack
driven through it under loss / delay / reordering / ECN marking.

The harness tests are the acceptance story for the loss-tolerant
transport: the stack has to converge to full in-order delivery under any
impairment schedule, NewReno vs DCTCP vs the seed engine must be
selectable by topology alone with bit-identical lossless behavior, and a
random-schedule property (hypothesis, with the deterministic fallback)
pins convergence in bounded steps."""
import struct

import jax.numpy as jnp
import numpy as np
import pytest

from repro.net import eth, frames as F, ipv4, rpc, tcp
from repro.net.stack import TcpStack, UdpStack
from repro.netem import (GilbertElliott, Link, LinkConfig, LinuxTcpClient,
                         StackEndpoint, run_transfer)
from repro.netem.link import _ce_mark
from tests._hyp_compat import given, settings, st

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")
MSS = 256
PAYLOAD = bytes(np.random.default_rng(7).integers(0, 256, 4000,
                                                  dtype=np.uint8))


# ---------------------------------------------------------------------------
# the link emulator alone (pure numpy, no stack)


def test_link_fixed_delay_preserves_order():
    link = Link(LinkConfig(delay=3))
    for i in range(4):
        link.send(bytes([i]), now=i)
    assert link.deliver(2) == []
    assert link.deliver(3) == [b"\x00"]
    assert link.deliver(10) == [b"\x01", b"\x02", b"\x03"]


def test_link_deterministic_replay():
    cfg = LinkConfig(delay=2, jitter=3, loss=0.3, reorder=0.3, seed=17)
    out = []
    for _ in range(2):
        link = Link(cfg)
        for i in range(200):
            link.send(bytes([i % 256]), now=i)
        out.append((link.deliver(10_000), dict(link.stats)))
    assert out[0] == out[1]
    assert out[0][1]["dropped_loss"] > 0


def test_link_reorder_swaps_frames():
    link = Link(LinkConfig(delay=1, reorder=1.0, reorder_extra=5, seed=0))
    link.send(b"a", now=0)
    link2 = Link(LinkConfig(delay=1, seed=0))
    link2.send(b"b", now=0)
    # the reordered frame arrives reorder_extra ticks later
    assert link.deliver(1) == [] and link.deliver(6) == [b"a"]
    assert link2.deliver(1) == [b"b"]


def test_gilbert_elliott_produces_bursts():
    cfg = LinkConfig(delay=1, gilbert=GilbertElliott(
        p_good_bad=0.2, p_bad_good=0.3, loss_bad=1.0), seed=5)
    link = Link(cfg)
    n = 400
    for i in range(n):
        link.send(b"x", now=i)
    lost = n - len(link.deliver(10_000))
    assert 0 < lost < n
    # burstiness: loss rate well above an i.i.d. chain with the same
    # per-frame entry probability would give isolated drops; the chain's
    # stationary bad fraction is p_gb/(p_gb+p_bg) = 0.4
    assert abs(lost / n - 0.4) < 0.15


def test_shaping_queue_drop_and_ecn_mark():
    frame = F.udp_rpc_frame(IP_C, IP_S, 5000, 7, b"payload-bytes")
    cfg = LinkConfig(delay=1, rate=16, queue_bytes=3 * len(frame),
                     ecn_threshold=len(frame))
    link = Link(cfg)
    for _ in range(5):
        link.send(frame, now=0)
    assert link.stats["dropped_queue"] == 2        # bounded queue
    assert link.stats["marked"] == 2               # above-threshold CE
    got = link.deliver(10_000)
    assert len(got) == 3
    # marked frames still parse with a valid IP checksum and ECN == CE
    marked = [f for f in got if f[15] & 0x3 == 3]
    assert len(marked) == 2
    p, l = F.to_batch(marked, 128)
    p, l, m = eth.parse(jnp.asarray(p), jnp.asarray(l))
    _, _, m2, ok = ipv4.parse(p, l)
    assert bool(ok[0]) and int(m2["ip_ecn"][0]) == 3


def test_ce_mark_handles_ip_level_frames():
    pkt = F.ipv4_packet(IP_S, IP_C, 6, b"\x00" * 20)
    marked = _ce_mark(pkt)
    p, l = F.to_batch([marked], 64)
    _, _, m, ok = ipv4.parse(jnp.asarray(p), jnp.asarray(l))
    assert bool(ok[0]) and int(m["ip_ecn"][0]) == 3


# ---------------------------------------------------------------------------
# stack-through-netem transfers (shared endpoints: compile once)


def _endpoint(policy):
    stack = TcpStack(IP_S, max_conns=4, cc_policy=policy,
                     options={"tcp_tx_buf": 16384, "mss": MSS})
    return StackEndpoint(stack, mss=MSS, rx_width=96)


_CACHE = {}


def _newreno():
    """One compiled NewReno endpoint shared across tests (the property
    test can't take pytest fixtures under the hypothesis fallback)."""
    if "nr" not in _CACHE:
        _CACHE["nr"] = _endpoint("newreno")
    return _CACHE["nr"]


@pytest.fixture(scope="module")
def newreno():
    return _newreno()


class TapLink(Link):
    """Link that records every frame offered to it (pre-impairment)."""

    def __init__(self, cfg):
        super().__init__(cfg)
        self.tap = []

    def send(self, frame, now):
        self.tap.append((now, frame))
        super().send(frame, now)


def _run(srv, cfg_s2c, cfg_c2s=None, payload=PAYLOAD, max_ticks=3000):
    srv.reset()
    client = LinuxTcpClient(IP_C, IP_S)
    l_cs = Link(cfg_c2s or LinkConfig(delay=2, seed=1))
    l_sc = Link(cfg_s2c)
    return run_transfer(srv, client, l_cs, l_sc, payload,
                        max_ticks=max_ticks), client


def test_client_ignores_late_duplicate_synack():
    """A delayed duplicate SYN-ACK (jitter past the keepalive SYN retry)
    must not rewind an established client's receive point."""
    client = LinuxTcpClient(IP_C, IP_S)
    synack = F.tcp_eth_frame(IP_S, IP_C, 80, client.sport, seq=7000,
                             ack=client.iss + 1, flags=tcp.SYN | tcp.ACK)
    client.on_frame(synack, 1)
    data = F.tcp_eth_frame(IP_S, IP_C, 80, client.sport, seq=7001,
                           ack=client.iss + 1, flags=tcp.ACK | tcp.PSH,
                           payload=b"hello")
    client.on_frame(data, 2)
    assert bytes(client.received) == b"hello"
    client.on_frame(synack, 3)                     # late duplicate copy
    assert client.rcv_nxt == 7006                  # not rewound
    more = F.tcp_eth_frame(IP_S, IP_C, 80, client.sport, seq=7006,
                           ack=client.iss + 1, flags=tcp.ACK | tcp.PSH,
                           payload=b" world")
    client.on_frame(more, 4)
    assert bytes(client.received) == b"hello world"


def test_lossless_transfer_completes(newreno):
    stats, client = _run(newreno, LinkConfig(delay=2, seed=2))
    assert stats.complete
    assert bytes(client.received) == PAYLOAD
    assert stats.link_stats["s2c"]["dropped_loss"] == 0


def test_loss_recovers_with_retransmission(newreno):
    stats, _ = _run(newreno, LinkConfig(delay=2, loss=0.05, seed=5))
    assert stats.complete
    assert stats.link_stats["s2c"]["dropped_loss"] > 0   # loss did happen
    cc = newreno.state["conn"]["cc"]
    assert int(cc["retx_fast"][0]) + int(cc["retx_timer"][0]) > 0


def test_heavy_loss_and_reordering_converge(newreno):
    stats, _ = _run(newreno, LinkConfig(
        delay=2, jitter=2, loss=0.1, reorder=0.2, seed=4), max_ticks=6000)
    assert stats.complete


def test_burst_loss_converges(newreno):
    stats, _ = _run(newreno, LinkConfig(
        delay=2, gilbert=GilbertElliott(0.05, 0.4), seed=9),
        max_ticks=6000)
    assert stats.complete


def test_dctcp_reacts_to_ecn_marks():
    srv = _endpoint("dctcp")
    stats, _ = _run(srv, LinkConfig(delay=1, rate=128, queue_bytes=4096,
                                    ecn_threshold=512, seed=5),
                    max_ticks=6000)
    assert stats.complete
    assert stats.link_stats["s2c"]["marked"] > 0
    cc = srv.state["conn"]["cc"]
    assert int(cc["marks"][0]) > 0
    assert int(cc["alpha"][0]) > 0                 # mark fraction learned


def test_lossless_behavior_bit_identical_across_policies(newreno):
    """Acceptance: NewReno vs DCTCP selectable purely by topology/tile
    parameter, with every emitted frame bit-identical to the seed engine
    on a lossless path."""
    taps = {}
    payload = PAYLOAD[:2000]
    for policy in (None, "newreno", "dctcp"):
        srv = newreno if policy == "newreno" else _endpoint(policy)
        srv.reset()
        client = LinuxTcpClient(IP_C, IP_S)
        l_cs = Link(LinkConfig(delay=2, seed=0))
        l_sc = TapLink(LinkConfig(delay=2, seed=0))
        stats = run_transfer(srv, client, l_cs, l_sc, payload,
                             max_ticks=500)
        assert stats.complete
        taps[policy] = l_sc.tap
    assert taps["newreno"] == taps[None]
    assert taps["dctcp"] == taps[None]


def test_udp_stack_composes_with_netem():
    """The emulator is stack-agnostic: a compiled UDP echo stack behind a
    lossy link serves a retrying client fixture."""
    import jax

    from repro.apps import echo
    stack = UdpStack([echo.make(port=7)], IP_S)
    state = stack.init_state()
    rx_tx = jax.jit(lambda s, p, l: stack.rx_tx(s, p, l))
    link_up = Link(LinkConfig(delay=1, loss=0.4, seed=8))
    link_dn = Link(LinkConfig(delay=1, loss=0.4, seed=9))
    req = F.udp_rpc_frame(IP_C, IP_S, 5000, 7,
                          rpc.np_frame(rpc.MSG_ECHO, 1, b"retry-me"))
    got = None
    for t in range(0, 400, 4):                     # client retry loop
        link_up.send(req, t)
        for fr in link_up.deliver(t + 1):
            p, l = F.to_batch([fr], 128)
            state, q, ql, alive, info = rx_tx(
                state, jnp.asarray(p), jnp.asarray(l))
            if bool(alive[0]):
                link_dn.send(bytes(np.asarray(q)[0, :int(ql[0])].tobytes()),
                             t + 1)
        for fr in link_dn.deliver(t + 2):
            got = fr
        if got:
            break
    assert got is not None and got.endswith(b"retry-me")


# ---------------------------------------------------------------------------
# satellite: random-schedule convergence property


@settings(max_examples=6, deadline=None)
@given(st.tuples(st.integers(0, 2 ** 16), st.integers(0, 12),
                 st.integers(0, 20), st.integers(1, 4), st.integers(0, 3),
                 st.integers(500, 3500)))
def test_random_schedule_always_converges(params):
    """Any seeded loss/reorder/delay schedule converges to full in-order
    delivery with the client's rcv_nxt == the server's snd_nxt, within a
    bounded tick budget (no permanent stalls)."""
    seed, loss_pct, reorder_pct, delay, jitter, size = params
    srv = _newreno()
    payload = PAYLOAD[:size]
    cfg = dict(delay=delay, jitter=jitter, loss=loss_pct / 100,
               reorder=reorder_pct / 100)
    stats, client = _run(
        srv, LinkConfig(seed=seed, **cfg),
        cfg_c2s=LinkConfig(seed=seed + 1, **cfg),
        payload=payload, max_ticks=6000)
    assert stats.complete, (params, stats)
    assert bytes(client.received) == payload
    assert client.rcv_nxt == srv.snd_nxt()         # full in-order delivery
