"""Host-side export decoders on hand-built device state: flight-recorder
ring wraparound, drop-table rendering, summary() top-N ordering — plus
the static drop-reason coverage lint."""
import dataclasses
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.obs import export, flight, lint, reasons

ORDER = ["eth_rx", "ip_rx", "udp_rx"]


def _pipe():
    return SimpleNamespace(order=list(ORDER))


def _trace_row(nstages, frame_id, step, visited, reason, base):
    row = [frame_id, step, sum(1 << i for i in visited), reason]
    for i in range(nstages):
        row += [base + 2 * i, base + 2 * i + 1] if i in visited else [0, 0]
    return row


def test_trace_rows_ring_wraparound():
    n = len(ORDER)
    obs = flight.make_obs(n, trace_entries=4)
    ring = np.zeros((4, flight.trace_width(n)), np.int32)
    # 6 sampled frames through a 4-deep ring: slots hold frames 2..5,
    # physically starting at slot 6 % 4 == 2
    for fid in range(6):
        ring[fid % 4] = _trace_row(n, fid, fid // 2, [0, 1],
                                   reasons.IP_CSUM if fid == 5 else 0,
                                   base=100 * fid)
    obs["trace"] = dataclasses.replace(
        obs["trace"], entries=jnp.asarray(ring),
        wr=jnp.asarray(6, jnp.int32))
    rows = export.trace_rows(obs)
    assert [r["frame_id"] for r in rows] == [2, 3, 4, 5]   # oldest first
    assert rows[0]["visited"] == [0, 1]
    assert rows[0]["enter"] == {0: 200, 1: 202}
    assert rows[0]["exit"] == {0: 201, 1: 203}
    assert rows[-1]["drop_reason"] == reasons.IP_CSUM
    # unwrapped ring (wr < depth): only the written prefix decodes
    obs["trace"] = dataclasses.replace(
        obs["trace"], wr=jnp.asarray(3, jnp.int32))
    assert [r["frame_id"] for r in export.trace_rows(obs)] == [4, 5, 2]


def _state(drops, node_row=None):
    n = len(ORDER)
    nodes = telemetry.make_node_log(n, n_entries=4)
    if node_row is not None:
        nodes = dataclasses.replace(
            nodes,
            entries=nodes.entries.at[0].set(jnp.asarray(node_row)),
            wr=jnp.asarray(1, jnp.int32))
    return {"telemetry": {"nodes": nodes,
                          "drops": jnp.asarray(drops, jnp.int32),
                          "obs": flight.make_obs(n)}}


def test_drop_table_nonzero_cells_only():
    drops = np.zeros((3, reasons.NUM_REASONS), np.int32)
    drops[1, reasons.IP_CSUM] = 7
    drops[1, reasons.IP_TTL] = 2
    drops[2, reasons.RUNT_UDP] = 1
    tab = export.drop_table(_state(drops), _pipe())
    assert tab == {"ip_rx": {"ip_csum": 7, "ip_ttl": 2},
                   "udp_rx": {"runt_udp": 1}}
    assert "eth_rx" not in tab                  # all-zero rows elided


def test_summary_top_n_ordering():
    drops = np.zeros((3, reasons.NUM_REASONS), np.int32)
    drops[1, reasons.IP_CSUM] = 50
    drops[2, reasons.RUNT_UDP] = 9
    drops[2, reasons.RPC_MAGIC] = 200
    drops[1, reasons.IP_TTL] = 1
    row = [[s, 10 * (i + 1), i, 5, i, 0, 0, 0]
           for i, s in enumerate([3, 3, 3])]
    text = export.summary(_state(drops, node_row=row), _pipe(), top=3)
    lines = text.splitlines()
    # per-tile counters from the latest node-log row
    assert any(l.startswith("udp_rx") and " 30 " in f" {l} " for l in lines)
    # top-3 drop reasons, descending, the 4th (count=1) cut
    start = lines.index("top drop reasons:") + 1
    ranked = [tuple(l.split()) for l in lines[start:start + 3]]
    assert ranked == [("udp_rx", "rpc_magic", "200"),
                      ("ip_rx", "ip_csum", "50"),
                      ("udp_rx", "runt_udp", "9")]
    assert len(lines) == start + 3              # ip_ttl did not make the cut


def test_reason_coverage_lint_passes():
    """Every registered tile that can squash `pred` attributes a drop
    reason code (satellite: static coverage check)."""
    assert lint.check_reason_coverage() == []
