"""Streaming executor (device-resident multi-batch scan).

Acceptance coverage for the streamed path:
  * `run_stream` output — outputs AND state, telemetry counters included —
    is bit-identical to N sequential `run` calls over UDP, TCP, and the
    ipinip-tunneled topology;
  * zero host transfers inside the scanned region (jaxpr/HLO inspection);
  * runtime ROUTE_SET between stream chunks takes effect on the next
    chunk without recompilation;
  * compile-time dead-stage pruning drops statically unreachable stages
    (and never prunes port-keyed routes — the runtime-rewritable CAMs);
  * `FrameArena` fill-in-place semantics and the `to_batch` error fix.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo
from repro.core.compiler import StackCompiler
from repro.net import frames as F, ipinip, rpc, tcp
from repro.net.stack import (TcpStack, UdpStack, ipinip_udp_topology,
                             udp_topology)

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")
TUN_C, TUN_S = F.ip("1.1.1.1"), F.ip("2.2.2.2")


def assert_trees_equal(a, b):
    la = jax.tree_util.tree_leaves_with_path(a)
    lb = {jax.tree_util.keystr(k): v
          for k, v in jax.tree_util.tree_leaves_with_path(b)}
    assert len(la) == len(lb)
    for k, v in la:
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(lb[jax.tree_util.keystr(k)]),
            err_msg=jax.tree_util.keystr(k))


def echo_frame(sport, req=1, port=7, payload=b"x", dst=IP_S):
    return F.udp_rpc_frame(IP_C, dst, sport, port,
                           rpc.np_frame(rpc.MSG_ECHO, req, payload))


def udp_arena(n_batches=3, batch=4, max_len=256):
    """Per-batch distinct traffic, including an unknown port and a corrupt
    frame so drops land in the telemetry counters."""
    arena = F.FrameArena(n_batches, batch, max_len)
    frames = []
    for i in range(n_batches * batch - 2):
        frames.append(echo_frame(5000 + i, req=i))
    frames.append(echo_frame(7000, req=98, port=4444))       # unknown port
    corrupt = bytearray(echo_frame(7001, req=99))
    corrupt[20] ^= 0xFF                                      # IP checksum
    frames.append(bytes(corrupt))
    arena.fill(frames)
    return arena


# ---------------------------------------------------------------------------
# bit-identity: streamed == N sequential batches (telemetry included)


def test_udp_run_stream_bit_identical():
    stack = UdpStack([echo.make(port=7, n_replicas=2)], IP_S)
    arena = udp_arena()
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    seq_state = stack.init_state()
    seq = {"tx_payload": [], "tx_len": [], "alive": []}
    for i in range(arena.n_batches):
        seq_state, q, ql, alive, info = stack.rx_tx(seq_state, p[i], l[i])
        seq["tx_payload"].append(q)
        seq["tx_len"].append(ql)
        seq["alive"].append(alive)

    st, outs = stack.run_stream(stack.init_state(), p, l)
    assert_trees_equal(st, seq_state)                 # telemetry included
    for k, rows in seq.items():
        np.testing.assert_array_equal(np.asarray(outs[k]),
                                      np.stack([np.asarray(r)
                                                for r in rows]))


def test_udp_run_stream_jit_matches_eager():
    stack = UdpStack([echo.make(port=7)], IP_S)
    arena = udp_arena(n_batches=2)
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)
    st_e, outs_e = stack.run_stream(stack.init_state(), p, l)
    st_j, outs_j = jax.jit(stack.run_stream)(stack.init_state(), p, l)
    assert_trees_equal(st_e, st_j)
    assert_trees_equal(outs_e, outs_j)


def test_ipinip_run_stream_bit_identical():
    apps = [echo.make(port=7)]
    stack = UdpStack(apps, IP_S, topo=ipinip_udp_topology(apps),
                     options={"outer_src": TUN_S, "outer_dst": TUN_C})

    def tunneled(sport, req):
        inner_udp = F.udp_datagram(IP_C, IP_S, sport, 7,
                                   rpc.np_frame(rpc.MSG_ECHO, req, b"tun"))
        inner_ip = F.ipv4_packet(IP_C, IP_S, 17, inner_udp)
        outer_ip = F.ipv4_packet(TUN_C, TUN_S, ipinip.PROTO_IPIP, inner_ip)
        return F.eth_frame(b"\x02\x00\x00\x00\x00\x01",
                           b"\x02\x00\x00\x00\x00\x02", 0x0800, outer_ip)

    arena = F.FrameArena(2, 2, 256)
    arena.fill([tunneled(5000, 1), echo_frame(5001, 2),   # plain one dies
                tunneled(5002, 3), tunneled(5003, 4)])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    seq_state = stack.init_state()
    rows = []
    for i in range(arena.n_batches):
        seq_state, q, ql, alive, info = stack.rx_tx(seq_state, p[i], l[i])
        rows.append((q, ql, alive))
    st, outs = stack.run_stream(stack.init_state(), p, l)
    assert_trees_equal(st, seq_state)
    np.testing.assert_array_equal(
        np.asarray(outs["alive"]), np.stack([np.asarray(r[2])
                                             for r in rows]))
    np.testing.assert_array_equal(
        np.asarray(outs["tx_payload"]), np.stack([np.asarray(r[0])
                                                  for r in rows]))


def test_tcp_run_stream_bit_identical():
    """The scan carry really threads engine state: SYN -> ACK -> data
    across three streamed batches matches three sequential rx calls."""
    mk = lambda: TcpStack(IP_S, max_conns=4)
    ref, stk = mk(), mk()

    syn = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=900, ack=0,
                          flags=tcp.SYN)
    st_r = ref.init_state()
    p0, l0 = F.to_batch([syn], 128)
    st_r, r0 = ref.rx(st_r, jnp.asarray(p0), jnp.asarray(l0))
    iss = int(r0["tcp_seq"][0])

    batches = [
        [syn],
        [F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=901, ack=iss + 1,
                         flags=tcp.ACK)],
        [F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=901, ack=iss + 1,
                         flags=tcp.ACK | tcp.PSH, payload=b"hello")],
    ]
    arena = F.FrameArena(3, 1, 128)
    arena.fill([b[0] for b in batches])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    seq_state = ref.init_state()
    seq_resps = []
    for i in range(3):
        seq_state, resps = ref.rx(seq_state, p[i], l[i])
        seq_resps.append(resps)
    st, outs = stk.run_stream(stk.init_state(), p, l)
    assert_trees_equal(st["conn"], seq_state["conn"])
    assert_trees_equal(st, seq_state)
    for k in seq_resps[0]:
        np.testing.assert_array_equal(
            np.asarray(outs["tcp_resps"][k]),
            np.stack([np.asarray(r[k]) for r in seq_resps]), err_msg=k)
    # the engine really advanced through the stream
    assert int(st["conn"]["rcv_nxt"][0]) == 901 + 5


# ---------------------------------------------------------------------------
# zero host syncs inside the scanned region (acceptance)


def test_run_stream_zero_host_transfers():
    stack = UdpStack([echo.make(port=7)], IP_S)
    arena = udp_arena(n_batches=2)
    state = stack.init_state()
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    fn = lambda st, pp, ll: stack.run_stream(st, pp, ll)
    closed = jax.make_jaxpr(fn)(state, p, l)
    prims = set()

    def walk(jaxpr):
        for eq in jaxpr.eqns:
            prims.add(eq.primitive.name)
            for v in eq.params.values():
                vs = v if isinstance(v, (tuple, list)) else (v,)
                for s in vs:
                    if isinstance(s, jax.core.ClosedJaxpr):
                        walk(s.jaxpr)
                    elif isinstance(s, jax.core.Jaxpr):
                        walk(s)

    walk(closed.jaxpr)
    assert "scan" in prims                 # the N batches are ONE loop
    assert not prims & {"pure_callback", "io_callback", "debug_callback",
                        "infeed", "outfeed", "device_put"}

    hlo = jax.jit(fn).lower(state, p, l).compile().as_text()
    low = hlo.lower()
    assert "infeed" not in low and "outfeed" not in low
    assert "send-to-host" not in low and "recv-from-host" not in low
    assert "while" in low                  # scan lowered device-resident


# ---------------------------------------------------------------------------
# runtime route rewrites between stream chunks (satellite)


def test_route_set_between_stream_chunks_no_recompile():
    stack = UdpStack([echo.make(port=7)], IP_S)
    traces = []

    def counted(st, p, l):
        traces.append(1)
        return stack.run_stream(st, p, l)

    fn = jax.jit(counted)
    arena = F.FrameArena(2, 2, 256)
    arena.fill([echo_frame(5000 + i, req=i, port=7777) for i in range(4)])
    p, l = jnp.asarray(arena.payload), jnp.asarray(arena.length)

    state = stack.init_state()
    state, outs = fn(state, p, l)
    assert not bool(np.asarray(outs["info"]["echo"]).any())   # port unbound

    # live CAM rewrite between chunks: bind 7777 to the echo node
    tbl = state["routes"]["udp_rx:udp_port"]
    state = dict(state)
    state["routes"] = dict(state["routes"])
    state["routes"]["udp_rx:udp_port"] = tbl.set_entry(
        15, 7777, stack.pipeline.order.index("echo"))

    state, outs = fn(state, p, l)
    assert bool(np.asarray(outs["info"]["echo"]).all())
    assert len(traces) == 1          # same compiled program served both


# ---------------------------------------------------------------------------
# dead-stage pruning (compile-time)


def test_dead_stage_is_pruned_and_output_unchanged():
    """A tile whose only in-edge contradicts an upstream static-field
    commitment (ip_proto=6 below a udp-only path) is dropped before
    tracing; the surviving pipeline is bit-identical to the clean one."""
    apps = lambda: [echo.make(port=7)]
    topo = udp_topology(apps())
    topo.add_tile("phantom", "controller", 3, 1)
    topo.add_route("udp_rx", "ip_proto", 6, "phantom")

    stack = UdpStack(apps(), IP_S, topo=topo)
    plain = UdpStack(apps(), IP_S)
    assert stack.pipeline.pruned == ["phantom"]
    assert "phantom" not in stack.pipeline.order
    assert stack.pipeline.order == plain.pipeline.order
    # the dead edge's CAM never materializes either
    assert "udp_rx:ip_proto" not in stack.pipeline.table_entries

    p, l = F.to_batch([echo_frame(5000)], 256)
    p, l = jnp.asarray(p), jnp.asarray(l)
    st_a = stack.init_state()
    st_b = plain.init_state()
    st_a, qa, qla, alive_a, _ = stack.rx_tx(st_a, p, l)
    st_b, qb, qlb, alive_b, _ = plain.rx_tx(st_b, p, l)
    np.testing.assert_array_equal(np.asarray(qa), np.asarray(qb))
    np.testing.assert_array_equal(np.asarray(qla), np.asarray(qlb))
    assert_trees_equal(st_a, st_b)


def test_port_keyed_routes_are_never_pruned():
    """udp_port/tcp_port CAMs are the runtime-rewritable surface: a node
    reachable only through a port key stays compiled even if no traffic
    matches it yet (ROUTE_SET may bind it live)."""
    topo = udp_topology([echo.make(port=7)])
    topo.add_tile("parked", "controller", 3, 1)
    topo.add_route("udp_rx", "udp_port", 9999, "parked")
    stack = UdpStack([echo.make(port=7)], IP_S, topo=topo)
    assert stack.pipeline.pruned == []
    assert "parked" in stack.pipeline.order


def test_prune_exempts_fields_reparsed_by_duplicated_tiles():
    """The ipinip pattern duplicates ip_rx to re-parse the inner header
    (paper §3.5), making ip_proto runtime-dependent: a keyed route on the
    inner value LOOKS contradictory to the outer commitment (4 vs 17) but
    fires at runtime — pruning must leave the whole field alone."""
    apps = [echo.make(port=7)]
    topo = ipinip_udp_topology(apps)
    # key the inner hop on the re-parsed inner protocol instead of const
    for r in topo.tile("ip_rx_inner").routes:
        if r.next_tile == "udp_rx":
            r.match, r.key = "ip_proto", 17
    stack = UdpStack(apps, IP_S, topo=topo,
                     options={"outer_src": TUN_S, "outer_dst": TUN_C})
    assert stack.pipeline.pruned == []
    assert "udp_rx" in stack.pipeline.order

    inner_udp = F.udp_datagram(IP_C, IP_S, 5000, 7,
                               rpc.np_frame(rpc.MSG_ECHO, 1, b"inner"))
    inner_ip = F.ipv4_packet(IP_C, IP_S, 17, inner_udp)
    outer_ip = F.ipv4_packet(TUN_C, TUN_S, ipinip.PROTO_IPIP, inner_ip)
    frame = F.eth_frame(b"\x02\x00\x00\x00\x00\x01",
                        b"\x02\x00\x00\x00\x00\x02", 0x0800, outer_ip)
    p, l = F.to_batch([frame], 256)
    state, q, ql, alive, info = stack.rx_tx(
        stack.init_state(), jnp.asarray(p), jnp.asarray(l))
    assert bool(alive[0]) and bool(info["echo"][0])


def test_prune_keeps_multi_path_nodes():
    """A node with one dead and one feasible in-edge survives."""
    topo = udp_topology([echo.make(port=7)])
    topo.add_tile("dual", "controller", 3, 1)
    topo.add_route("udp_rx", "ip_proto", 6, "dual")      # dead edge
    topo.add_route("udp_rx", "const", None, "dual")      # feasible edge
    compiler = StackCompiler(topo, bindings={"echo": echo.make(port=7)},
                             options={"local_ip": IP_S})
    pipe = compiler.compile("eth_rx")
    assert pipe.pruned == [] and "dual" in pipe.order


# ---------------------------------------------------------------------------
# FrameArena + to_batch (satellite)


def test_frame_arena_fill_clears_stale_bytes():
    arena = F.FrameArena(2, 2, 64)
    used = arena.fill([b"\xAA" * 48, b"\xBB" * 10, b"\xCC" * 5])
    assert used == 2
    assert arena.length[0, 0] == 48 and arena.length[1, 1] == 0
    arena.fill([b"\xDD" * 4])                    # shorter refill
    assert arena.length[0, 0] == 4
    assert arena.payload[0, 0, 4:].max() == 0    # no stale 0xAA tail
    assert arena.payload[1].max() == 0


def test_frame_arena_errors_name_the_offender():
    arena = F.FrameArena(1, 2, 32)
    with pytest.raises(ValueError, match="frame 1 is 40 bytes"):
        arena.fill([b"x" * 8, b"y" * 40])
    with pytest.raises(ValueError, match="exceed the arena's capacity"):
        arena.fill([b"x"] * 3)


def test_to_batch_autosizes_and_raises_clearly():
    payload, length = F.to_batch([b"abc", b"defgh"])     # no max_len
    assert payload.shape == (2, 5)
    assert length.tolist() == [3, 5]
    with pytest.raises(ValueError, match="frame 1 is 5 bytes"):
        F.to_batch([b"abc", b"defgh"], max_len=4)
    assert F.to_batch([], )[0].shape == (0, 1)           # empty is fine


# ---------------------------------------------------------------------------
# perf regression smoke (slow lane)


@pytest.mark.slow
def test_streamed_pps_not_below_per_batch():
    """The streamed path must never regress below the per-batch harness
    pattern (fresh pack + transfer + dispatch + sync per batch) — the
    same measurement `make bench-stream` runs, smaller window, relaxed
    threshold (the quantitative >=3x gate lives in the bench)."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.bench_stream import measure

    r = measure(n_batches=16, batch=8, repeats=3)
    assert r["speedup"] >= 1.0, (
        f"streamed {r['streamed_pps']:.0f}pps < per-batch "
        f"{r['per_batch_pps']:.0f}pps")
