"""Per-architecture smoke tests (assignment requirement): a reduced config
of each family runs one forward/train step on CPU with finite outputs and
correct shapes; decode paths match the full forward; every (arch x shape)
cell builds its dry-run input specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.configs.shapes import SHAPES, applicable, grid
from repro.models import model

pytestmark = pytest.mark.slow
from repro.optim import adamw


def _batch(cfg, B=2, S=16, key=7):
    ks = jax.random.key(key)
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jax.random.normal(ks, (B, S, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = jax.random.randint(ks, (B, S), 0, cfg.vocab)
    if cfg.frontend == "vision_stub":
        batch["image_embeds"] = jax.random.normal(
            jax.random.fold_in(ks, 1), (B, cfg.n_image_embeds, cfg.d_model)
        ) * 0.02
    batch["labels"] = jax.random.randint(jax.random.fold_in(ks, 2), (B, S),
                                         0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg)
    logits = model.forward(cfg, params, batch)
    assert logits.shape == (2, 16, cfg.v_pad)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in forward"
    # one full train step (loss + grads + optimizer)
    opt = adamw.init(params)
    loss, grads = jax.value_and_grad(
        lambda p: model.loss_fn(cfg, p, batch))(params)
    assert bool(jnp.isfinite(loss))
    new_params, opt, m = adamw.update(grads, opt, params)
    assert bool(jnp.isfinite(m["grad_norm"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                     params, new_params)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).supports_decode])
def test_smoke_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    params = model.init_params(cfg, jax.random.key(1))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.key(3), (B, S), 0, cfg.vocab)
    fb = {"tokens": toks}
    if cfg.frontend == "vision_stub":
        fb["image_embeds"] = jax.random.normal(
            jax.random.key(4), (B, cfg.n_image_embeds, cfg.d_model)) * 0.02
    full = model.forward(cfg, params, fb)
    cache = model.init_cache(cfg, B, S, stacked=False)
    start = cfg.n_image_embeds if cfg.frontend == "vision_stub" else 0
    if start:   # image positions enter via prefill in VLM serving
        pytest.skip("vlm decode-from-scratch not meaningful over image slots")
    errs = []
    for t in range(S):
        lg, cache = model.decode_step(cfg, params, cache, toks[:, t],
                                      jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) < 5e-5, f"decode/forward divergence: {max(errs)}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_shape_grid_and_skips(arch):
    cfg = get_config(arch)
    cells = grid(cfg)
    names = {s.name for s in cells}
    if arch == "hubert-xlarge":
        assert names == {"train_4k", "prefill_32k"}
    elif arch in ("gemma3-12b", "recurrentgemma-2b", "falcon-mamba-7b"):
        assert names == {"train_4k", "prefill_32k", "decode_32k",
                         "long_500k"}
    else:
        assert names == {"train_4k", "prefill_32k", "decode_32k"}


def test_total_runnable_cells_is_32():
    n = sum(len(grid(get_config(a))) for a in ARCH_IDS)
    assert n == 32


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_configs_match_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, None, 202048),
        "olmoe-1b-7b": (16, 2048, 16, 16, None, 50304),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
    }[arch]
    L, d, H, kv, ff, V = spec
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == V
    assert cfg.n_heads == H and cfg.n_kv_heads == kv
    if ff is not None and ff:
        assert cfg.d_ff == ff
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.n_experts == 128 and cfg.top_k == 1
        n = model.count_params(cfg)
        assert 3.8e11 < n < 4.2e11, f"{n/1e9:.1f}B != ~400B"
    if arch == "olmoe-1b-7b":
        assert cfg.n_experts == 64 and cfg.top_k == 8
        n = model.count_params(cfg)
        assert 6.0e9 < n < 8.0e9
    if arch == "falcon-mamba-7b":
        assert cfg.d_inner == 8192 and cfg.ssm_state == 16
        n = model.count_params(cfg)
        assert 6.5e9 < n < 8.5e9
    if arch == "gemma3-12b":
        n = model.count_params(cfg)
        assert 1.0e10 < n < 1.4e10
