"""Management plane: in-band control frames through the compiled pipeline
(paper §3.6, §4.5, §4.6).

Everything here drives the stack the way a remote operator would: wire-
format UDP command frames in, parsed ack / readback frames out.  No test
calls `control.controller_apply` — the compiled `mgmt` tile is the unit
under test."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import echo
from repro.core import control, deadlock, telemetry
from repro.core.compiler import CompileError, StackCompiler
from repro.mgmt.console import MgmtConsole, command_frame, dump_counters
from repro.net import frames as F, ipinip, rpc, tcp
from repro.net.stack import (TcpStack, UdpStack, ipinip_udp_topology,
                             tcp_topology, udp_topology,
                             udp_topology_with_nat)

IP_C = F.ip("10.0.0.2")
IP_S = F.ip("10.0.0.1")
VIP = F.ip("20.0.0.9")
VIP2 = F.ip("20.0.0.7")
TUN_C, TUN_S = F.ip("1.1.1.1"), F.ip("2.2.2.2")
MP = 9909


def batch(frames, max_len=256):
    p, l = F.to_batch(frames, max_len)
    return jnp.asarray(p), jnp.asarray(l)


def echo_frame(dst_ip, sport, port=7, payload=b"x", req=1):
    return F.udp_rpc_frame(IP_C, dst_ip, sport, port,
                           rpc.np_frame(rpc.MSG_ECHO, req, payload))


# ---------------------------------------------------------------------------
# tentpole: NAT_SET over the wire, applied live, versioned ack


def test_nat_set_live_via_management_frame():
    """One compiled pipeline: a NAT_SET UDP command frame is accepted,
    acked with a version, and the *next* batch translates with the new
    mapping — no recompile, no direct controller_apply call."""
    apps = [echo.make(port=7, n_replicas=2)]
    stack = UdpStack(apps, IP_S, topo=udp_topology_with_nat(apps),
                     nat_entries=[(VIP, IP_S)], mgmt_port=MP)
    assert "mgmt" in stack.pipeline.order
    state = stack.init_state()

    # the old mapping serves, the new VIP does not exist yet
    state, _, _, alive, info = stack.rx_tx(
        state, *batch([echo_frame(VIP, 5000)]))
    assert bool(alive[0]) and bool(info["echo"][0])

    con = MgmtConsole(stack)
    state, ack = con.set_nat(state, 0, VIP2, IP_S)
    assert ack["status"] == 1 and ack["version"] == 1
    assert int(state["mgmt"]["ctrl"].version) == 1

    # next batch: the rewritten slot translates the new virtual IP and the
    # reply still carries a checksum valid for it (RFC 1624 fixup path)
    state, q, ql, alive, info = stack.rx_tx(
        state, *batch([echo_frame(VIP2, 5001)]))
    assert bool(alive[0]) and bool(info["echo"][0])

    # convergence polling over the same in-band path
    state, converged = con.wait_converged(state, 1)
    assert converged


def test_ack_rides_the_tx_chain_as_a_real_frame():
    """The ack is a parseable UDP frame built by the ordinary TX tiles:
    reply addressing is swapped, the RPC req_id is echoed."""
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    fr = command_frame(IP_C, IP_S, 5999, MP, control.OP_VERSION, req_id=77)
    state, q, ql, alive, info = stack.rx_tx(state, *batch([fr]))
    assert bool(alive[0]) and bool(info["mgmt"][0])
    from repro.mgmt.console import parse_response
    r = parse_response(bytes(np.asarray(q)[0, :int(ql[0])].tobytes()))
    assert r["req_id"] == 77 and r["status"] == 1 and r["version"] == 0
    # and the frame really is addressed back to the client
    import struct
    assert struct.unpack_from("!I", bytes(np.asarray(q)[0].tobytes()),
                              14 + 16)[0] == IP_C


# ---------------------------------------------------------------------------
# tentpole: LOG_READ telemetry readback + REQ_BUF drop semantics


def test_log_read_returns_real_counter_row():
    stack = UdpStack([echo.make(port=7, n_replicas=2)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    state, *_ = stack.rx_tx(state, *batch(
        [echo_frame(IP_S, 5000 + i, req=i) for i in range(3)]))

    con = MgmtConsole(stack)
    echo_idx = con.node_ids["echo"]
    # the fused node append lands at batch egress, so age 0 is the newest
    # *completed* batch — the data batch above, not the readback batch
    state, r = con.read_counters(state, "echo", age=0)
    assert r["status"] == 1
    row = r["row"]
    assert row["step"] == 1 and row["packets_in"] == 3 and row["drops"] == 0
    assert row["noc_latency"] > 0 and row["tile_index"] == echo_idx
    # and the row matches the RingLog the executor keeps
    want = np.asarray(telemetry.entry_at(
        stack.pipeline.node_log(state, "echo"), 1))
    assert [row["step"], row["packets_in"], row["drops"],
            row["noc_latency"], row["tile_index"]] == want[:5].tolist()


def test_log_read_beyond_req_buf_is_dropped_then_served_on_retry():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    con = MgmtConsole(stack)
    eth_idx = con.node_ids["eth_rx"]
    reads = [(control.OP_LOG_READ, 0, eth_idx, 0, 0)] * (telemetry.REQ_BUF + 2)
    state, resps = con.roundtrip(state, reads)
    assert [r["status"] for r in resps] == [1] * telemetry.REQ_BUF + [0, 0]
    # dropped requests left the version untouched and the fill visible
    assert int(stack.pipeline.node_log(state, "eth_rx").req_fill) == \
        telemetry.REQ_BUF
    # clients re-request; the buffer drained between batches
    state, resps = con.roundtrip(state, reads[:1])
    assert resps[0]["status"] == 1


def test_req_fill_unit_semantics():
    """Satellite: read_entry now models fill/drain honestly."""
    log = telemetry.make_log(8)
    accepted = []
    for i in range(telemetry.REQ_BUF + 2):
        log, entry, ok = telemetry.read_entry(log, jnp.int32(i))
        accepted.append(bool(ok))
    assert accepted == [True] * telemetry.REQ_BUF + [False, False]
    assert int(log.req_fill) == telemetry.REQ_BUF
    log = telemetry.drain(log)
    assert int(log.req_fill) == 0
    log, _, ok = telemetry.read_entry(log, jnp.int32(0))
    assert bool(ok)


# ---------------------------------------------------------------------------
# tentpole: ROUTE_SET — runtime CAM rewrite through the management port


def test_route_set_binds_new_port_live():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    probe = batch([echo_frame(IP_S, 5000, port=7777)])
    state, _, _, _, info = stack.rx_tx(state, *probe)
    assert not bool(info["echo"][0])              # port unknown

    con = MgmtConsole(stack)
    state, ack = con.set_route(state, "udp_rx:udp_port", 15, 7777, "echo")
    assert ack["status"] == 1
    state, _, _, alive, info = stack.rx_tx(state, *probe)
    assert bool(alive[0]) and bool(info["echo"][0])


# ---------------------------------------------------------------------------
# satellite: HEALTH_SET end-to-end — a drained replica stops being picked


def test_health_set_drains_replica_end_to_end():
    apps = [echo.make(port=7, n_replicas=2)]
    stack = UdpStack(apps, IP_S, mgmt_port=MP)
    state = stack.init_state()
    con = MgmtConsole(stack)

    state, ack = con.drain_replica(state, "echo", 0)
    assert ack["status"] == 1
    assert not bool(state["dispatch"]["echo"].healthy[0])

    frames = [echo_frame(IP_S, 6000 + i, req=i) for i in range(4)]
    state, *_ = stack.rx_tx(state, *batch(frames))
    served = np.asarray(state["apps"]["echo"]["served"])
    assert served.tolist() == [0, 4]              # replica 0 never selected

    state, ack = con.restore_replica(state, "echo", 0)
    assert ack["status"] == 1 and ack["version"] == 2
    state, *_ = stack.rx_tx(state, *batch(frames))
    served = np.asarray(state["apps"]["echo"]["served"])
    assert served[0] > 0                          # back in rotation


# ---------------------------------------------------------------------------
# tentpole: ctrl NoC isolation


def test_ctrl_topology_deadlock_analysis_is_independent():
    """The ctrl NoC passes its own analysis, and a pathological control
    chain fails the ctrl analysis without touching the data verdict."""
    topo = udp_topology([echo.make(port=7)])
    from repro.mgmt.plane import bind_mgmt
    bind_mgmt(topo, MP)
    assert deadlock.analyze(topo, noc="data").ok
    assert deadlock.analyze(topo, noc="ctrl").ok

    # a control chain that re-acquires its channels: ctrl analysis fails,
    # data analysis is unaffected
    topo.add_chain("ctrl", "eth_rx.m", "ctrl", "eth_rx.m")
    rep = deadlock.analyze(topo, noc="ctrl")
    assert not rep.ok and rep.self_conflicts
    assert deadlock.analyze(topo, noc="data").ok


def test_control_route_joining_dataplane_chain_is_rejected():
    topo = tcp_topology(with_nat=False)
    topo.add_route("ctrl", "const", None, "tcp_rx")   # ctrl -> dataplane
    errs = topo.validate()
    assert any("crosses" in e for e in errs)
    with pytest.raises(CompileError):
        StackCompiler(topo, options={"local_ip": IP_S})
    # and the reverse direction is equally rejected
    topo2 = tcp_topology(with_nat=False)
    topo2.add_route("tcp_rx", "const", None, "ctrl")
    assert any("crosses" in e for e in topo2.validate())


def test_mixed_noc_chain_is_rejected():
    topo = tcp_topology(with_nat=False)
    topo.add_chain("ip_rx", "ctrl")
    assert any("mixes nocs" in e for e in topo.validate())


def test_ctrl_pipeline_compiles_from_topology():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    order = stack.ctrl_pipe.order
    assert order[0] == "ctrl_in" and order[1] == "ctrl"
    assert all(n.endswith(".m") for n in order[2:])
    # every dataplane tile got a management endpoint (the mgmt tile's own
    # ctrl-NoC interface is ctrl_in, at its coordinate)
    data_tiles = {t.name for t in stack.topo.tiles_on("data")}
    assert {n[:-2] for n in order[2:]} == data_tiles - {"mgmt"}


# ---------------------------------------------------------------------------
# management on the TCP stack (UDP port binding rides alongside TCP)


def test_tcp_stack_management_binding_mixed_batch():
    stack = TcpStack(IP_S, mgmt_port=MP)
    state = stack.init_state()
    syn = F.tcp_eth_frame(IP_C, IP_S, 4000, 80, seq=900, ack=0,
                          flags=tcp.SYN)
    mf = command_frame(IP_C, IP_S, 5999, MP, control.OP_VERSION, req_id=5)
    state, resps, q, ql, mask = stack.rx_mgmt(state, *batch([syn, mf]))
    # the TCP engine still answers the SYN and never sees the UDP frame
    assert bool(resps["emit"][0])
    assert int(resps["tcp_flags"][0]) == tcp.SYN | tcp.ACK
    assert not bool(resps["emit"][1])
    # the management frame got an in-band reply
    assert np.asarray(mask).tolist() == [False, True]
    from repro.mgmt.console import parse_response
    r = parse_response(bytes(np.asarray(q)[1, :int(ql[1])].tobytes()))
    assert r["req_id"] == 5 and r["status"] == 1


def test_tcp_stack_console_roundtrip():
    stack = TcpStack(IP_S, mgmt_port=MP)
    state = stack.init_state()
    con = MgmtConsole(stack)
    state, _ = con.version(state)       # one completed batch writes rows
    state, r = con.read_counters(state, "tcp_rx", age=0)
    assert r["status"] == 1
    assert r["row"]["tile_index"] == con.node_ids["tcp_rx"]
    assert r["row"]["step"] == 1        # the VERSION batch, not the read


# ---------------------------------------------------------------------------
# satellite: ipinip-encapsulated UDP topology via insert_on_path only


def test_ipinip_udp_topology_golden_roundtrip():
    """decap -> inner ip -> app -> encap, built purely by config edits; a
    golden tunneled frame round-trips and the reply is re-encapsulated
    toward the tunnel peer."""
    import struct
    apps = [echo.make(port=7)]
    topo = ipinip_udp_topology(apps)
    assert topo.validate() == []
    stack = UdpStack(apps, IP_S, topo=topo,
                     options={"outer_src": TUN_S, "outer_dst": TUN_C})
    order = stack.pipeline.order
    assert order.index("ipip_decap") < order.index("ip_rx_inner") < \
        order.index("udp_rx")
    assert order.index("ip_tx") < order.index("ipip_encap") < \
        order.index("eth_tx")
    state = stack.init_state()

    inner_udp = F.udp_datagram(IP_C, IP_S, 5000, 7,
                               rpc.np_frame(rpc.MSG_ECHO, 9, b"tunneled"))
    inner_ip = F.ipv4_packet(IP_C, IP_S, 17, inner_udp)
    outer_ip = F.ipv4_packet(TUN_C, TUN_S, ipinip.PROTO_IPIP, inner_ip)
    frame = F.eth_frame(b"\x02\x00\x00\x00\x00\x01",
                        b"\x02\x00\x00\x00\x00\x02", 0x0800, outer_ip)
    state, q, ql, alive, info = stack.rx_tx(state, *batch([frame], 512))
    assert bool(alive[0]) and bool(info["echo"][0])

    reply = bytes(np.asarray(q)[0, :int(ql[0])].tobytes())
    # outer header: IPIP toward the tunnel peer
    assert reply[14 + 9] == ipinip.PROTO_IPIP
    assert struct.unpack_from("!II", reply, 14 + 12) == (TUN_S, TUN_C)
    # inner packet: the echo reply with swapped addressing
    i = 14 + 20
    assert reply[i + 9] == 17
    assert struct.unpack_from("!II", reply, i + 12) == (IP_S, IP_C)
    sport, dport = struct.unpack_from("!HH", reply, i + 20)
    assert (sport, dport) == (7, 5000)
    assert reply[i + 20 + 8 + rpc.HLEN:] == b"tunneled"
    # an un-tunneled plain frame no longer matches the ingress route
    state, _, _, _, info = stack.rx_tx(state, *batch(
        [echo_frame(IP_S, 5001)], 512))
    assert not bool(info["echo"][0])


# ---------------------------------------------------------------------------
# management traffic coexists with data traffic in one batch


def test_mixed_data_and_mgmt_batch_one_pipeline_run():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    frames = [echo_frame(IP_S, 5000, payload=b"data"),
              command_frame(IP_C, IP_S, 5999, MP, control.OP_VERSION,
                            req_id=3),
              echo_frame(IP_S, 5001, payload=b"more")]
    state, q, ql, alive, info = jax.jit(stack.rx_tx)(state, *batch(frames))
    assert np.asarray(alive).tolist() == [True, True, True]
    assert np.asarray(info["echo"]).tolist() == [True, False, True]
    assert np.asarray(info["mgmt"]).tolist() == [False, True, False]
    # data rows echo their body; the mgmt row carries the response words
    from repro.mgmt.console import parse_response
    r = parse_response(bytes(np.asarray(q)[1, :int(ql[1])].tobytes()))
    assert r["req_id"] == 3 and r["status"] == 1


def test_dump_counters_covers_every_tile():
    stack = UdpStack([echo.make(port=7)], IP_S, mgmt_port=MP)
    state = stack.init_state()
    state, *_ = stack.rx_tx(state, *batch([echo_frame(IP_S, 5000)]))
    state, counters = dump_counters(stack, state)
    assert set(counters) == set(stack.pipeline.order)
    # age-0 rows describe the newest *completed* batch (the fused node
    # append lands at batch egress): the single echo frame above, not the
    # dump batch itself
    assert counters["eth_rx"]["packets_in"] == 1
