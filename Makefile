# Test tiers
#
#   make test-fast   tier-1 verify loop: everything except @slow
#                    (distributed subprocess suite, per-arch model smokes,
#                    trainer loops, big kernel sweeps) — about a minute
#   make test        the full suite (what CI / the PR gate runs)
#   make bench       the paper-benchmark battery

PY ?= python
# src for the repro package, the repo root for the benchmarks package
PYTHONPATH := src:.$(if $(PYTHONPATH),:$(PYTHONPATH),)
export PYTHONPATH

.PHONY: test-fast test bench bench-mgmt bench-tcp-loss bench-stream \
        bench-rpc-tail bench-obs bench-shard lint-reasons

test-fast:
	$(PY) -m pytest -q -m "not slow"

test:
	$(PY) -m pytest -q

# static drop-reason coverage: every registered tile that can squash
# `pred` must attribute a reason code (also run as a test in
# tests/test_export.py)
lint-reasons:
	$(PY) -m repro.obs.lint

bench:
	$(PY) benchmarks/run.py

# management-plane contention regression check (paper: control traffic
# never contends with the dataplane)
bench-mgmt:
	$(PY) benchmarks/bench_mgmt.py

# loss-tolerant transport gate: goodput + p99 recovery latency through
# the netem link at 0.1% / 1% loss (fails on stall or < 20% goodput)
bench-tcp-loss:
	$(PY) benchmarks/bench_tcp_loss.py

# streaming-executor gate: streamed UDP echo pps must be >= 3x the
# per-batch baseline; writes BENCH_stream.json (the perf trajectory)
bench-stream:
	$(PY) benchmarks/bench_stream.py

# direct-attached serving gate: LM request p99 through the compiled stack
# (lm_serve tile inside run_stream) must be <= 0.5x the host-mediated
# baseline; APPENDS a trajectory entry to BENCH_rpc_tail.json
bench-rpc-tail:
	$(PY) benchmarks/bench_rpc_tail.py

# observability gate: pull (flight recorder @1/64 + histograms) AND push
# (postcards + series ring + SLO watchdog) must each stay within 10% of
# the telemetry-only run_stream baseline, with zero host callbacks in
# the scanned region; APPENDS to BENCH_obs.json
bench-obs:
	$(PY) benchmarks/bench_obs.py

# sharded-dataplane gate: RSS-replicated stack under shard_map on a
# host-simulated 8-device mesh — certified (no collectives, no host
# callbacks, bit-identical egress) projected aggregate must be >= 4x the
# single-device baseline; APPENDS to BENCH_shard.json
bench-shard:
	$(PY) benchmarks/bench_shard.py
