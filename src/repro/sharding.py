"""Sharding policy: logical-axis -> mesh-axis mapping for the whole framework.

The production mesh is (data=16, model=16) per pod, with an optional leading
"pod" axis for multi-pod runs (pure DP across pods).  Model code never names
mesh axes directly; it asks the active :class:`Policy` for PartitionSpecs so
the same code runs on 1 CPU device (policy disabled) and on a 512-chip mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Policy:
    """Maps logical tensor axes onto mesh axes.

    dp:     axes carrying the batch dimension, e.g. ("data",) or ("pod", "data").
    tp:     tensor-parallel axis name ("model") or None.
    fsdp:   axis that shards parameters FSDP-style ("data") or None.
    enabled: when False every helper degenerates to no-op (single device).
    """

    dp: Tuple[str, ...] = ()
    tp: Optional[str] = None
    fsdp: Optional[str] = None
    enabled: bool = False
    # decode-mode optimization (§Perf): slice activations on the fsdp axis
    # along the contraction dim so weights stay resident (no per-step FSDP
    # all-gather); XLA partial-sums and all-reduces the tiny activations.
    resident_decode: bool = False

    # ---- activation specs -------------------------------------------------
    def batch(self, *trailing: Optional[str]) -> P:
        """Spec for an activation whose dim0 is the (global) batch."""
        return P(self.dp if self.dp else None, *trailing)

    def constrain(self, x, spec: P):
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    # Shorthand used throughout the model code: hidden states (B, S, D).
    def hidden(self, x, seq_axis: Optional[str] = None):
        return self.constrain(x, self.batch(seq_axis, None))

    # ---- divisibility-aware choices ----------------------------------------
    def axis_size(self, name: Optional[str]) -> int:
        if not self.enabled or name is None:
            return 1
        from repro.launch.compat import get_context_mesh
        mesh = get_context_mesh()
        if mesh is None or mesh.empty:  # pragma: no cover - defensive
            return 1
        return dict(mesh.shape).get(name, 1)

    def tp_size(self) -> int:
        return self.axis_size(self.tp)

    def shard_heads(self, n_heads: int, n_kv: int) -> bool:
        """True when attention can be head-sharded on the tp axis."""
        t = self.tp_size()
        return t > 1 and n_heads % t == 0 and n_kv % t == 0

    def dp_size(self) -> int:
        n = 1
        for a in self.dp:
            n *= self.axis_size(a)
        return n

    def cache_spec(self, batch: int, head_dim: int = 0) -> P:
        """Sharding for KV caches (B, S_max, KV, hd).

        resident_decode (§Perf): shard head_dim on tp — the per-position
        cache write is then local (no gather-update-scatter collectives)
        and attention partial-sums over hd with a tiny all-reduce.
        Baseline: shard the sequence dim on tp (flash-decode style).
        Batch goes on dp when it divides; long-context (batch=1) keeps
        sequence sharding for capacity."""
        if not self.enabled:
            return P()
        b_ok = batch % max(1, self.dp_size()) == 0
        if (self.resident_decode and b_ok and self.tp
                and head_dim % max(1, self.tp_size()) == 0):
            return P(self.dp, None, None, self.tp)
        if b_ok:
            return P(self.dp, self.tp, None, None)
        return P(None, tuple(self.dp) + ((self.tp,) if self.tp else ()),
                 None, None)

    def state_spec(self, batch: int, inner_div: bool = True) -> P:
        """Sharding for O(1) recurrent states (B, inner, ...)."""
        if not self.enabled:
            return P()
        b = self.dp if batch % max(1, self.dp_size()) == 0 else None
        return P(b, self.tp if inner_div else None)

    def maybe(self, name: Optional[str], size: int) -> Optional[str]:
        """Return the mesh axis only if `size` divides evenly over it."""
        if name is None or not self.enabled:
            return None
        return name if size % self.axis_size(name) == 0 else None


SINGLE = Policy()  # disabled policy for single-device smoke tests / unit tests


def make_policy(mesh: Mesh, multi_pod: bool = False,
                resident_decode: bool = False) -> Policy:
    dp = ("pod", "data") if multi_pod else ("data",)
    return Policy(dp=dp, tp="model", fsdp="data", enabled=True,
                  resident_decode=resident_decode)


# ---- parameter sharding rules ----------------------------------------------
# Parameters are pytrees of arrays; leaves carry a logical spec via the
# companion "specs" pytree produced by each model's `param_specs(cfg)`.
# Rules (trailing dims; leading stacked-layer dims are always unsharded):
#   ("fsdp", "tp")  - e.g. w_in (D, F): D on data, F on model
#   ("tp", "fsdp")  - e.g. w_out (F, D), embedding (V, D)
#   ("tp",)         - bias rows on the tp-sharded output dim
#   ()              - replicated (norm scales, small vectors)


def logical_to_spec(logical: Sequence[Optional[str]], policy: Policy,
                    shape: Sequence[int]) -> P:
    """Translate a logical spec tuple to a PartitionSpec, dropping any axis
    that does not divide evenly (defensive: keeps lowering robust)."""
    if not policy.enabled:
        return P()
    names = {"tp": policy.tp, "fsdp": policy.fsdp, "dp": policy.dp}
    out = []
    # right-align: logical spec describes the *trailing* dims
    pad = len(shape) - len(logical)
    out.extend([None] * pad)
    for dim, log in zip(shape[pad:], logical):
        if log is None:
            out.append(None)
            continue
        ax = names.get(log, log)
        if isinstance(ax, tuple):
            out.append(ax if dim % max(1, _tuple_size(policy, ax)) == 0 else None)
        else:
            out.append(policy.maybe(ax, dim))
    return P(*out)


def _tuple_size(policy: Policy, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= policy.axis_size(a)
    return n


def named_sharding_tree(specs_tree, shapes_tree, mesh: Mesh, policy: Policy):
    """Produce a pytree of NamedSharding matching a pytree of logical specs."""
    def one(spec, shaped):
        pspec = logical_to_spec(spec, policy, shaped.shape)
        return NamedSharding(mesh, pspec)
    return jax.tree.map(one, specs_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
