"""UDP echo application tile (paper §6.3)."""
from __future__ import annotations

import jax.numpy as jnp


def make(name: str = "echo", port: int = 7, n_replicas: int = 1):
    from repro.net.stack import AppDecl

    def process(state, body, blen, meta, active, replica):
        # echo: body unchanged; count per-replica service
        counts = state["served"]
        counts = counts.at[replica].add(active.astype(jnp.int32))
        return {"served": counts}, body, blen

    state = {"served": jnp.zeros((n_replicas,), jnp.int32)}
    return AppDecl(name=name, port=port, n_replicas=n_replicas,
                   policy="round_robin", process=process, state=state)
