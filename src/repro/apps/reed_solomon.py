"""Reed-Solomon erasure-coding application tile (paper §5.1, §6.5).

Stateless RS(8,2) encoder on 4 KiB requests: the client sends a 4 KiB data
block over UDP RPC; the reply carries the 1 KiB of parity (two 512 B
shards).  Replicated with round-robin dispatch — any request can go to any
copy.  Each replica logs served bytes (the paper's bandwidth metadata).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rs_encode import ops as rs_ops

K, P = 8, 2
REQ = 4096
RESP = REQ // K * P     # 1024


def make(name: str = "rs", port: int = 9000, n_replicas: int = 4,
         use_pallas: bool = False):
    from repro.net.stack import AppDecl

    def process(state, body, blen, meta, active, replica):
        data = body[:, :REQ]
        parity = rs_ops.encode_blocks(data, k=K, p=P, use_pallas=use_pallas)
        out = jnp.zeros_like(body)
        out = out.at[:, :RESP].set(parity)
        served = state["bytes"].at[replica].add(
            jnp.where(active, REQ, 0).astype(jnp.int32))
        ops = state["ops"].at[replica].add(active.astype(jnp.int32))
        return {"bytes": served, "ops": ops}, out, \
            jnp.where(active, RESP, blen)

    state = {"bytes": jnp.zeros((n_replicas,), jnp.int32),
             "ops": jnp.zeros((n_replicas,), jnp.int32)}
    return AppDecl(name=name, port=port, n_replicas=n_replicas,
                   policy="round_robin", process=process, state=state)
