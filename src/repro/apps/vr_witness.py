"""Viewstamped Replication witness appliance (paper §5.2, §6.6).

The witness validates the leader and tracks operation order without
executing operations: one leader + witness(es) + replica(s) give
linearizable reads at far lower cost than full consensus replicas.

Protocol (modeled on VR-revisited as used by the paper):
  PREPARE(view, op_num, digest) -> PREPARE_OK(view, op_num) iff the view
      matches and op_num == last_op + 1 (gap-free ordering); the witness
      appends the digest to its log.
  READ_VERIFY(view) -> OK iff view is current (leader lease validation —
      this is the message on the critical path of consistent reads).
  START_VIEW(view') -> adopt the higher view (view change).

State is per *shard* (paper: one witness tile per shard, dispatched by
destination port).  All state is fixed-shape arrays -> shard-affine
dispatch, serializable, control-plane inspectable.

Request payload (big-endian u32 words): [opcode, view, op_num, digest]
Reply payload:                          [status, view, op_num, 0]
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B

OP_PREPARE, OP_READ_VERIFY, OP_START_VIEW = 1, 2, 3
ST_OK, ST_REJECT = 0, 1
LOG = 1024


def init_state(n_shards: int):
    return {
        "view": jnp.zeros((n_shards,), jnp.uint32),
        "last_op": jnp.zeros((n_shards,), jnp.uint32),
        "log": jnp.zeros((n_shards, LOG), jnp.uint32),   # digests by op_num
        "prepares": jnp.zeros((n_shards,), jnp.int32),
        "reads": jnp.zeros((n_shards,), jnp.int32),
    }


def witness_step(state, shard, opcode, view, op_num, digest, active):
    """Processed sequentially within the batch (a scan), so requests see
    every earlier request's effects — ordering is the whole point of a
    witness.  `shard` selects each request's tile (port-match dispatch)."""
    import jax

    is_prep = active & (opcode == OP_PREPARE)
    is_read = active & (opcode == OP_READ_VERIFY)
    is_vc = active & (opcode == OP_START_VIEW)

    def step(st, xs):
        sh, is_p, is_r, is_v, vw, op, dg = xs
        cur = st["view"][sh]
        lo = st["last_op"][sh]
        vok = vw == cur
        pok = is_p & vok & (op == lo + 1)
        rok = is_r & vok
        vcok = is_v & (vw > cur)
        st = dict(st)
        st["last_op"] = st["last_op"].at[sh].set(jnp.where(pok, op, lo))
        st["log"] = st["log"].at[sh, op % LOG].set(
            jnp.where(pok, dg, st["log"][sh, op % LOG]))
        st["view"] = st["view"].at[sh].set(jnp.where(vcok, vw, cur))
        st["prepares"] = st["prepares"].at[sh].add(pok.astype(jnp.int32))
        st["reads"] = st["reads"].at[sh].add(is_r.astype(jnp.int32))
        return st, pok | rok | vcok

    state, ok = jax.lax.scan(
        step, state, (shard, is_prep, is_read, is_vc, view, op_num, digest))
    status = jnp.where(ok, ST_OK, ST_REJECT)
    return state, status


def make(name: str = "vr", base_port: int = 9100, n_shards: int = 1):
    """App tile for the UDP stack: one witness tile per shard, port-match
    dispatch (paper: 'distribute work to the VR tiles by matching on the
    destination port number')."""
    from repro.net.stack import AppDecl

    def process(state, body, blen, meta, active, replica):
        opcode = B.be32(body, 0).astype(jnp.uint32)
        view = B.be32(body, 4).astype(jnp.uint32)
        op_num = B.be32(body, 8).astype(jnp.uint32)
        digest = B.be32(body, 12).astype(jnp.uint32)
        shard = (meta["dst_port"] - base_port).astype(jnp.int32) % n_shards
        state, status = witness_step(state, shard, opcode, view, op_num,
                                     digest, active)
        out = jnp.zeros_like(body)
        out = B.set_be32(out, 0, status.astype(jnp.uint32))
        out = B.set_be32(out, 4, state["view"][shard])
        out = B.set_be32(out, 8, op_num)
        return state, out, jnp.where(active, 16, blen)

    return AppDecl(name=name, port=base_port, n_replicas=n_shards,
                   policy="port_match", process=process,
                   state=init_state(n_shards))
