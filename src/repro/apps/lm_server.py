"""LM serving application behind the Beehive stack.

Requests arrive as RPC-over-UDP (MSG_LM_GENERATE):
  payload = [session u32 | n_gen u16 | n_prompt u16 | prompt tokens u16...]
Reply:
  payload = [session u32 | n_out u16 | tokens u16 ...]
An error reply carries a sentinel in n_out (>= ERR_BASE) and no tokens.
MSG_LM_RELEASE closes a session explicitly: payload = [session u32].

Two serving paths share this wire format:

  * **host-mediated** (`LmServerApp.handle`): the CPU-attached baseline —
    the host parses the request, drives the `ServeEngine`, and frames the
    reply.  Sessions are LRU-tracked; slot exhaustion evicts (or returns an
    error reply) instead of raising.
  * **direct-attached** (`make_tile` + the `lm_serve` tile in net/tiles.py):
    the paper's headline path — session/KV state lives in the compiled
    stack's state pytree (the `run_stream` scan carry), and each arriving
    MSG_LM_GENERATE triggers one on-device decode step with the reply built
    in the same device program.  Prompts are prefilled host-side via the
    engine and *adopted* into device state (`adopt_engine`); thereafter the
    ingest -> decode -> reply loop never touches the host.

Sessions are flows: the upstream dispatch pins a session to an engine
replica; live migration moves the session blob between engines and flips
the dispatch table (paper §5.3 semantics, with the KV cache playing the
role of the TCP connection state).
"""
from __future__ import annotations

import dataclasses
import struct
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.net import bytesops as B
from repro.serve.engine import ServeEngine

REQ_HLEN = 8           # session u32 | n_gen u16 | n_prompt u16
REP_HLEN = 6           # session u32 | n_out u16

# error sentinels carried in the reply's n_out field (a real reply can
# never reach them: tokens are u16, so n_out tops out near payload_len/2)
ERR_BASE = 0xFFF0
ERR_BAD_REQUEST = 0xFFFF   # malformed / truncated request payload
ERR_NO_SLOT = 0xFFFE       # engine full and eviction disabled
ERR_NO_SESSION = 0xFFFD    # unknown session (or no prompt to open one)


def encode_request(session: int, n_gen: int, prompt: List[int]) -> bytes:
    return struct.pack("!IHH", session, n_gen, len(prompt)) + \
        b"".join(struct.pack("!H", t) for t in prompt)


def encode_release(session: int) -> bytes:
    return struct.pack("!I", session)


def decode_request(payload: bytes) -> Tuple[int, int, List[int], bool]:
    """Bounds-checked parse mirroring rpc.parse's ok-flag convention:
    returns (session, n_gen, prompt, ok) and never raises on truncation."""
    if len(payload) < REQ_HLEN:
        return 0, 0, [], False
    session, n_gen, n_prompt = struct.unpack("!IHH", payload[:REQ_HLEN])
    end = REQ_HLEN + 2 * n_prompt
    if end > len(payload):
        return session, n_gen, [], False
    toks = list(struct.unpack(f"!{n_prompt}H", payload[REQ_HLEN:end])) \
        if n_prompt else []
    return session, n_gen, toks, True


def encode_reply(session: int, tokens: List[int]) -> bytes:
    return struct.pack("!IH", session, len(tokens)) + \
        b"".join(struct.pack("!H", t) for t in tokens)


def encode_error(session: int, code: int) -> bytes:
    assert code >= ERR_BASE
    return struct.pack("!IH", session, code)


def decode_reply(payload: bytes) -> Tuple[int, List[int], bool]:
    """Returns (session, tokens, ok).  Error replies decode as
    (session, [], True) — use :func:`reply_error` to read the code."""
    if len(payload) < REP_HLEN:
        return 0, [], False
    session, n = struct.unpack("!IH", payload[:REP_HLEN])
    if n >= ERR_BASE:
        return session, [], True
    end = REP_HLEN + 2 * n
    if end > len(payload):
        return session, [], False
    toks = list(struct.unpack(f"!{n}H", payload[REP_HLEN:end])) if n else []
    return session, toks, True


def reply_error(payload: bytes) -> Optional[int]:
    """The error sentinel of a reply, or None for a success reply."""
    if len(payload) < REP_HLEN:
        return None
    _, n = struct.unpack("!IH", payload[:REP_HLEN])
    return n if n >= ERR_BASE else None


class LmServerApp:
    """Host-side application loop around a ServeEngine (the CPU-attached
    baseline).  Sessions are LRU-ordered; when the engine is full a new
    session evicts the least-recently-used one (``evict="lru"``, default)
    or gets an ERR_NO_SLOT reply (``evict=None``).  Malformed requests get
    an error reply — no ingest path raises."""

    def __init__(self, engine: ServeEngine, evict: Optional[str] = "lru"):
        self.engine = engine
        self.evict = evict
        self.session_map: "OrderedDict[int, int]" = OrderedDict()

    def handle(self, payload: bytes) -> bytes:
        session, n_gen, prompt, ok = decode_request(payload)
        if not ok:
            return encode_error(session, ERR_BAD_REQUEST)
        if session not in self.session_map:
            if not prompt:
                # a follow-up for a session we don't hold (evicted, or
                # never opened) — nothing to prefill from
                return encode_error(session, ERR_NO_SESSION)
            if not self.engine.has_free_slot():
                if self.evict == "lru" and self.session_map:
                    victim = next(iter(self.session_map))
                    self.release(victim)
                else:
                    return encode_error(session, ERR_NO_SLOT)
            try:
                sid = self.engine.new_session(np.asarray(prompt, np.int32))
            except RuntimeError:
                return encode_error(session, ERR_NO_SLOT)
            self.session_map[session] = sid
        self.session_map.move_to_end(session)
        sid = self.session_map[session]
        toks = self.engine.generate(sid, n_gen)
        return encode_reply(session, toks)

    def handle_release(self, payload: bytes) -> bytes:
        """MSG_LM_RELEASE: explicit session close."""
        if len(payload) < 4:
            return encode_error(0, ERR_BAD_REQUEST)
        session = struct.unpack("!I", payload[:4])[0]
        if self.release(session):
            return encode_reply(session, [])
        return encode_error(session, ERR_NO_SESSION)

    def release(self, session: int) -> bool:
        sid = self.session_map.pop(session, None)
        if sid is None:
            return False
        self.engine.release(sid)
        return True

    # ---- migration --------------------------------------------------------
    def migrate_session_to(self, session: int, other: "LmServerApp") -> None:
        sid = self.session_map.pop(session)
        blob = self.engine.migrate_out(sid)
        other.session_map[session] = other.engine.migrate_in(blob)


# ---------------------------------------------------------------------------
# direct-attached serving: the device-resident LM tile
#
# The tile's state (cache / pos / last_tok / used / sess_ids) lives in the
# compiled stack's state pytree, so `run_stream` threads it through the
# lax.scan carry — a request arriving in batch i advances its session for
# batch i+1 with zero host involvement.  Prompts are prefilled host-side
# through the ordinary ServeEngine and adopted via `adopt_engine`.


@dataclasses.dataclass
class LmTileDecl:
    """Binding for a `lm_serve` tile (passed to the compiler by node name,
    like an AppDecl).  `state` is the template the tile init copies."""
    name: str
    cfg: Any
    params: Any
    max_sessions: int
    max_seq: int
    state: Dict[str, Any]


def make_tile(cfg, params, max_sessions: int = 4, max_seq: int = 64,
              name: str = "lm") -> LmTileDecl:
    from repro.models import model
    state = {
        "cache": model.init_cache(cfg, max_sessions, max_seq),
        "pos": jnp.zeros((max_sessions,), jnp.int32),
        "last_tok": jnp.zeros((max_sessions,), jnp.int32),
        "used": jnp.zeros((max_sessions,), bool),
        "sess_ids": jnp.zeros((max_sessions,), jnp.uint32),
        "served": jnp.zeros((), jnp.int32),
    }
    return LmTileDecl(name=name, cfg=cfg, params=params,
                      max_sessions=max_sessions, max_seq=max_seq,
                      state=state)


def adopt_engine(tile_state: Dict[str, Any], engine: ServeEngine,
                 session_map: Dict[int, int]) -> Dict[str, Any]:
    """Install a host-prefilled engine's sessions into a device tile state
    (e.g. ``state["apps"]["lm"]``).  `session_map` maps client session id
    -> engine slot (`LmServerApp.session_map` works as-is).  Arrays are
    copied, so a donated stream run can never invalidate the engine's own
    buffers."""
    M = engine.M
    ids = np.zeros((M,), np.uint32)
    used = np.zeros((M,), bool)
    for sess, slot in session_map.items():
        ids[slot] = sess
        used[slot] = bool(engine.used[slot])
    st = dict(tile_state)
    st.update(
        cache=jax.tree.map(jnp.array, engine.cache),
        pos=jnp.array(engine.pos),
        last_tok=jnp.array(engine.last_tok),
        used=jnp.asarray(used),
        sess_ids=jnp.asarray(ids),
    )
    return st


def tile_process(decl: LmTileDecl, st: Dict[str, Any], body, blen, active):
    """One batch through the device LM tile: parse requests, run ONE decode
    step for every session addressed by a valid request, build replies.

    Pure JAX — no host callbacks, jittable inside the run_stream scan.
    Semantics: a request generates exactly one token (clients stream
    follow-up requests for more, the serving decode loop); duplicate
    requests for one session within a batch coalesce into a single step.
    Invalid rows (short body, unknown session, out-of-room session) get an
    error reply and advance nothing.
    """
    from repro.models import model
    cfg, params, S = decl.cfg, decl.params, decl.max_seq

    sess = B.be32(body, 0)                              # (B,) uint32
    n_gen = B.be16(body, 4)
    ok_len = (blen >= REQ_HLEN) & (n_gen >= 1)
    match = st["used"][None, :] & (st["sess_ids"][None, :] == sess[:, None])
    hit = match.any(axis=1)
    slot = jnp.argmax(match, axis=1)                    # (B,) garbage if ~hit
    room = (st["pos"] < S)[slot]
    valid = active & ok_len & hit & room
    adv = (match & valid[:, None]).any(axis=0)          # (M,) sessions to step

    def run_step(cache, last_tok, pos):
        logits, ncache = model.decode_step(cfg, params, cache, last_tok, pos)
        return ncache, model.greedy_token(cfg, logits)

    def skip_step(cache, last_tok, pos):
        return cache, last_tok

    # skip the model entirely on batches with no LM traffic (mixed streams)
    cache, nxt = jax.lax.cond(adv.any(), run_step, skip_step,
                              st["cache"], st["last_tok"], st["pos"])
    new_pos = st["pos"] + adv.astype(jnp.int32)
    new_last = jnp.where(adv, nxt, st["last_tok"])

    tok = new_last[slot].astype(jnp.uint32)             # (B,)
    out = jnp.zeros_like(body)
    out = B.set_be32(out, 0, sess)
    n_out = jnp.where(
        valid, jnp.uint32(1),
        jnp.where(~ok_len, jnp.uint32(ERR_BAD_REQUEST),
                  jnp.where(~hit, jnp.uint32(ERR_NO_SESSION),
                            jnp.uint32(ERR_NO_SLOT))))   # session out of room
    out = B.set_be16(out, 4, n_out)
    out = B.set_be16(out, 6, jnp.where(valid, tok, jnp.uint32(0)))
    out_blen = jnp.where(valid, REP_HLEN + 2, REP_HLEN).astype(blen.dtype)

    new_st = dict(st)
    new_st.update(cache=cache, pos=new_pos, last_tok=new_last,
                  served=st["served"] + valid.sum(dtype=jnp.int32))
    return new_st, out, out_blen
