"""LM serving application behind the Beehive stack.

Requests arrive as RPC-over-UDP (MSG_LM_GENERATE):
  payload = [session u32 | n_gen u16 | n_prompt u16 | prompt tokens u16...]
Reply:
  payload = [session u32 | n_out u16 | tokens u16 ...]

The app tile couples the packet path (pure JAX parse/build) with the
ServeEngine (KV-cache slots).  Sessions are flows: the upstream dispatch
pins a session to an engine replica; live migration moves the session blob
between engines and flips the dispatch table (paper §5.3 semantics, with
the KV cache playing the role of the TCP connection state).
"""
from __future__ import annotations

import struct
from typing import Dict, List, Tuple

import numpy as np

from repro.serve.engine import ServeEngine


def encode_request(session: int, n_gen: int, prompt: List[int]) -> bytes:
    return struct.pack("!IHH", session, n_gen, len(prompt)) + \
        b"".join(struct.pack("!H", t) for t in prompt)


def decode_request(payload: bytes) -> Tuple[int, int, List[int]]:
    session, n_gen, n_prompt = struct.unpack("!IHH", payload[:8])
    toks = [struct.unpack("!H", payload[8 + 2 * i:10 + 2 * i])[0]
            for i in range(n_prompt)]
    return session, n_gen, toks


def encode_reply(session: int, tokens: List[int]) -> bytes:
    return struct.pack("!IH", session, len(tokens)) + \
        b"".join(struct.pack("!H", t) for t in tokens)


def decode_reply(payload: bytes) -> Tuple[int, List[int]]:
    session, n = struct.unpack("!IH", payload[:6])
    toks = [struct.unpack("!H", payload[6 + 2 * i:8 + 2 * i])[0]
            for i in range(n)]
    return session, toks


class LmServerApp:
    """Host-side application loop around a ServeEngine."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self.session_map: Dict[int, int] = {}   # client session -> slot

    def handle(self, payload: bytes) -> bytes:
        session, n_gen, prompt = decode_request(payload)
        if session not in self.session_map:
            sid = self.engine.new_session(np.asarray(prompt, np.int32))
            self.session_map[session] = sid
        sid = self.session_map[session]
        toks = self.engine.generate(sid, n_gen)
        return encode_reply(session, toks)

    # ---- migration --------------------------------------------------------
    def migrate_session_to(self, session: int, other: "LmServerApp") -> None:
        sid = self.session_map.pop(session)
        blob = self.engine.migrate_out(sid)
        other.session_map[session] = other.engine.migrate_in(blob)
