from repro.apps import echo, reed_solomon, vr_witness
