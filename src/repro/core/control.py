"""Control plane (paper §3.6, §4.5).

Beehive runs management on a separate, narrower NoC so control traffic
never contends with dataplane chains in the deadlock dependency graph.
Here the control plane is modeled as:

  * a separate TopologyConfig (noc="ctrl") with its own deadlock check,
  * an internal-controller tile that receives RPCs over the reliable
    transport (TCP), decodes (op, table, key, value) commands, applies them
    to the target tiles' runtime tables, and returns a confirmation,
  * versioned state updates: every applied command bumps a version counter
    so external controllers can confirm convergence.

Command encoding (RPC payload, all big-endian u32):
  [op, target_tile_id, a, b, c]
  op: 1 = NAT_SET    (a=slot, b=virtual_ip, c=physical_ip)
      2 = ROUTE_SET  (a=slot, b=match_key,  c=next_tile_id)
      3 = HEALTH_SET (a=replica_idx, b=0|1)
      4 = LOG_READ   (a=log_id, b=entry_idx)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

OP_NAT_SET = 1
OP_ROUTE_SET = 2
OP_HEALTH_SET = 3
OP_LOG_READ = 4


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ControllerState:
    version: jnp.ndarray        # () int32 — bumped per applied command
    last_op: jnp.ndarray        # () int32
    acks: jnp.ndarray           # () int32 — confirmations sent


def make_controller() -> ControllerState:
    z = jnp.zeros((), jnp.int32)
    return ControllerState(version=z, last_op=z, acks=z)


def decode_command(payload_words: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """payload_words: (5,) uint32 — [op, target, a, b, c]."""
    return {"op": payload_words[0].astype(jnp.int32),
            "target": payload_words[1].astype(jnp.int32),
            "a": payload_words[2].astype(jnp.int32),
            "b": payload_words[3].astype(jnp.int32),
            "c": payload_words[4].astype(jnp.int32)}


def apply_nat_set(nat_table, cmd):
    """nat_table: {"virt": (S,), "phys": (S,)} — NAT vip->pip mapping."""
    slot = cmd["a"]
    return {
        "virt": nat_table["virt"].at[slot].set(cmd["b"].astype(jnp.uint32)),
        "phys": nat_table["phys"].at[slot].set(cmd["c"].astype(jnp.uint32)),
    }


def apply_route_set(route_table, cmd):
    return route_table.set_entry(cmd["a"], cmd["b"], cmd["c"])


def apply_health_set(dispatch, cmd):
    from repro.core.scaleout import DispatchState
    return dataclasses.replace(
        dispatch, healthy=dispatch.healthy.at[cmd["a"]].set(cmd["b"] != 0))


def controller_apply(ctrl: ControllerState, cmd,
                     tables: Dict[str, Any]) -> Tuple[ControllerState,
                                                      Dict[str, Any],
                                                      jnp.ndarray]:
    """Apply one decoded command to the table store.  Returns (ctrl',
    tables', ack_word).  Dispatch on `op` is data-dependent, so every
    branch is computed and selected — cheap for tiny tables, and keeps the
    whole control plane jittable."""
    new_tables = dict(tables)
    is_nat = cmd["op"] == OP_NAT_SET
    is_route = cmd["op"] == OP_ROUTE_SET
    is_health = cmd["op"] == OP_HEALTH_SET

    if "nat" in tables:
        upd = apply_nat_set(tables["nat"], cmd)
        new_tables["nat"] = jax.tree.map(
            lambda n, o: jnp.where(is_nat, n, o), upd, tables["nat"])
    if "route" in tables:
        upd = apply_route_set(tables["route"], cmd)
        new_tables["route"] = jax.tree.map(
            lambda n, o: jnp.where(is_route, n, o), upd, tables["route"])
    if "dispatch" in tables:
        upd = apply_health_set(tables["dispatch"], cmd)
        new_tables["dispatch"] = jax.tree.map(
            lambda n, o: jnp.where(is_health, n, o), upd,
            tables["dispatch"])

    applied = is_nat | is_route | is_health
    ctrl = ControllerState(
        version=ctrl.version + applied.astype(jnp.int32),
        last_op=jnp.where(applied, cmd["op"], ctrl.last_op),
        acks=ctrl.acks + 1,
    )
    ack = (jnp.uint32(0xAC0000) | ctrl.version.astype(jnp.uint32))
    return ctrl, new_tables, ack
