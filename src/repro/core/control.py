"""Control plane (paper §3.6, §4.5).

Beehive runs management on a separate, narrower NoC so control traffic
never contends with dataplane chains in the deadlock dependency graph.
Here the control plane is modeled as:

  * a separate TopologyConfig (noc="ctrl") with its own deadlock check,
  * an internal-controller tile that receives RPCs over the reliable
    transport (TCP), decodes (op, table, key, value) commands, applies them
    to the target tiles' runtime tables, and returns a confirmation,
  * versioned state updates: every applied command bumps a version counter
    so external controllers can confirm convergence.

Command encoding (RPC payload, all big-endian u32):
  [op, target_tile_id, a, b, c]
  op: 1 = NAT_SET        (a=slot, b=virtual_ip, c=physical_ip)
      2 = ROUTE_SET      (target=table_id, a=slot, b=match_key, c=next_node)
      3 = HEALTH_SET     (target=dispatch_group, a=replica_idx, b=0|1)
      4 = LOG_READ       (a=log_id, b=entry_age; 0 = newest)
      5 = VERSION        (read the convergence counter, no mutation)
      6 = LOG_READ_RANGE (a=log_id, b=start_age, c=count <= MAX_RANGE):
                         bulk counter streaming — one request buffer slot,
                         up to MAX_RANGE rows in one response frame
      7 = RATE_SET       (a=bucket slot, b=udp port or -1 to clear,
                         c=rate | burst<<16 in packets/batch): per-port
                         token bucket applied at the dispatch tile
      8 = CC_SET         (a=knob: 0=policy engine-wide (b=0 newreno /
                         1 dctcp), 1=cwnd, 2=ssthresh; target=conn index,
                         b=value): live congestion-control knobs
      9 = TRACE_SET      (a=enable 0|1, b=sample shift: record 1 in
                         2**b frames): flight-recorder control — both
                         knobs are runtime state, no retrace
     10 = HISTO_READ     (a=row: node index, or num_nodes for the
                         end-to-end row): one 16-bucket occupancy
                         histogram row, wide-response format
     11 = DROP_READ      (a=node index): one drop-reason count row
                         (repro.obs.reasons codes, NUM_REASONS wide),
                         wide-response format
     12 = SLO_SET        (target=rule slot, a=metric_id<<16 | node_index,
                         b=raise threshold or -1 to disable the slot,
                         c=clear threshold): install one watchdog rule
                         over the series ring (repro.obs.slo) — live,
                         no retrace.  target=-1, b>0 instead sets the
                         series window length to b batches.
     13 = SERIES_READ    (target=node index, a=window age; 0 = newest
                         completed window): one node's per-window
                         counter deltas from the series ring
                         (repro.obs.series), wide-response format
     14 = GROUP_READ     (target=dispatch group index): one replica
                         group's live state — [n_replicas, healthy
                         bitmap, per-replica served counters...] —
                         wide-response format.  healthy is the *live*
                         bitmap (HEALTH_SET earlier in the same batch
                         is visible); served counters are snapshots
                         through the previous batch, like LOG_READ

Response encoding (RPC payload, all big-endian u32, 8 words fixed):
  [op, version, status, w0, w1, w2, w3, w4]
  status: writes -> 1 applied / 0 rejected; LOG_READ -> 1 served /
  0 dropped (request buffer full — re-request); VERSION -> 1.
  For LOG_READ, w0..w4 carry the telemetry counter row
  [step, packets_in, drops, noc_latency_cycles, tile_index].
  LOG_READ_RANGE responses are longer: [op, version, served_count,
  served_count * 5 row words] (served_count = 0 means dropped).
  HISTO_READ / DROP_READ reuse the wide layout: [op, version,
  served_word_count, OBS_ROW_WORDS table words] (0 = bad row / absent
  table).  Both serve the device tables as of the *previous* batch's
  egress — the same staleness window as LOG_READ.
  SERIES_READ also uses the wide layout; its served words are
  [windows_closed, window_len, frames, drops, bytes, occ_p99_bucket,
  retx] for the requested (window, node) cell block.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import telemetry

OP_NAT_SET = 1
OP_ROUTE_SET = 2
OP_HEALTH_SET = 3
OP_LOG_READ = 4
OP_VERSION = 5
OP_LOG_READ_RANGE = 6
OP_RATE_SET = 7
OP_CC_SET = 8
OP_TRACE_SET = 9
OP_HISTO_READ = 10
OP_DROP_READ = 11
OP_SLO_SET = 12
OP_SERIES_READ = 13
OP_GROUP_READ = 14

CMD_WORDS = 5
CMD_BYTES = 4 * CMD_WORDS
RESP_WORDS = 8
RESP_BYTES = 4 * RESP_WORDS
ROW_WORDS = 5           # counter-row words served per log entry
MAX_RANGE = 8           # entries per LOG_READ_RANGE response frame
RANGE_RESP_WORDS = 3 + ROW_WORDS * MAX_RANGE
RANGE_RESP_BYTES = 4 * RANGE_RESP_WORDS
OBS_ROW_WORDS = 24      # HISTO_READ / DROP_READ / SERIES_READ row width
OBS_RESP_BYTES = 4 * (3 + OBS_ROW_WORDS)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ControllerState:
    version: jnp.ndarray        # () int32 — bumped per applied command
    last_op: jnp.ndarray        # () int32
    acks: jnp.ndarray           # () int32 — confirmations sent


def make_controller() -> ControllerState:
    # distinct buffers per field: donated entry points (stream_fn) reject
    # a state pytree that aliases one buffer across leaves
    return ControllerState(version=jnp.zeros((), jnp.int32),
                           last_op=jnp.zeros((), jnp.int32),
                           acks=jnp.zeros((), jnp.int32))


def decode_command(payload_words: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """payload_words: (5,) uint32 — [op, target, a, b, c]."""
    return {"op": payload_words[0].astype(jnp.int32),
            "target": payload_words[1].astype(jnp.int32),
            "a": payload_words[2].astype(jnp.int32),
            "b": payload_words[3].astype(jnp.int32),
            "c": payload_words[4].astype(jnp.int32)}


def apply_nat_set(nat_table, cmd):
    """nat_table: {"virt": (S,), "phys": (S,)} — NAT vip->pip mapping."""
    slot = cmd["a"]
    return {
        "virt": nat_table["virt"].at[slot].set(cmd["b"].astype(jnp.uint32)),
        "phys": nat_table["phys"].at[slot].set(cmd["c"].astype(jnp.uint32)),
    }


def apply_route_set(route_table, cmd):
    return route_table.set_entry(cmd["a"], cmd["b"], cmd["c"])


def apply_health_set(dispatch, cmd):
    from repro.core.scaleout import DispatchState
    return dataclasses.replace(
        dispatch, healthy=dispatch.healthy.at[cmd["a"]].set(cmd["b"] != 0))


def controller_apply(ctrl: ControllerState, cmd,
                     tables: Dict[str, Any]) -> Tuple[ControllerState,
                                                      Dict[str, Any],
                                                      jnp.ndarray]:
    """Apply one decoded command to the table store.  Returns (ctrl',
    tables', ack_word).  Dispatch on `op` is data-dependent, so every
    branch is computed and selected — cheap for tiny tables, and keeps the
    whole control plane jittable."""
    new_tables = dict(tables)
    is_nat = cmd["op"] == OP_NAT_SET
    is_route = cmd["op"] == OP_ROUTE_SET
    is_health = cmd["op"] == OP_HEALTH_SET

    if "nat" in tables:
        upd = apply_nat_set(tables["nat"], cmd)
        new_tables["nat"] = jax.tree.map(
            lambda n, o: jnp.where(is_nat, n, o), upd, tables["nat"])
    if "route" in tables:
        upd = apply_route_set(tables["route"], cmd)
        new_tables["route"] = jax.tree.map(
            lambda n, o: jnp.where(is_route, n, o), upd, tables["route"])
    if "dispatch" in tables:
        upd = apply_health_set(tables["dispatch"], cmd)
        new_tables["dispatch"] = jax.tree.map(
            lambda n, o: jnp.where(is_health, n, o), upd,
            tables["dispatch"])

    applied = is_nat | is_route | is_health
    ctrl = ControllerState(
        version=ctrl.version + applied.astype(jnp.int32),
        last_op=jnp.where(applied, cmd["op"], ctrl.last_op),
        acks=ctrl.acks + 1,
    )
    ack = (jnp.uint32(0xAC0000) | ctrl.version.astype(jnp.uint32))
    return ctrl, new_tables, ack


# ---------------------------------------------------------------------------
# in-band response encoding + telemetry readback servicing (paper §4.6) —
# used by the management tile (repro.mgmt.plane) compiled into the stack


def encode_response(op, version, status,
                    entry_words=None) -> jnp.ndarray:
    """One (RESP_WORDS,) uint32 management-response payload."""
    if entry_words is None:
        entry_words = jnp.zeros((5,), jnp.uint32)
    head = jnp.stack([jnp.asarray(op).astype(jnp.uint32),
                      jnp.asarray(version).astype(jnp.uint32),
                      jnp.asarray(status).astype(jnp.uint32)])
    return jnp.concatenate([head, entry_words.astype(jnp.uint32)])


def encode_range_response(op, version, served, rows) -> jnp.ndarray:
    """One (RANGE_RESP_WORDS,) uint32 bulk-readback payload:
    [op, version, served_count, served*ROW_WORDS row words, zero pad]."""
    head = jnp.stack([jnp.asarray(op).astype(jnp.uint32),
                      jnp.asarray(version).astype(jnp.uint32),
                      jnp.asarray(served).astype(jnp.uint32)])
    return jnp.concatenate([head, rows.reshape(-1).astype(jnp.uint32)])


def encode_obs_response(op, version, served, row_words) -> jnp.ndarray:
    """One (RANGE_RESP_WORDS,) uint32 wide payload for HISTO_READ /
    DROP_READ: [op, version, served_word_count, OBS_ROW_WORDS table
    words, zero pad] — same frame layout as LOG_READ_RANGE so consoles
    reuse one wide-response parser."""
    head = jnp.stack([jnp.asarray(op).astype(jnp.uint32),
                      jnp.asarray(version).astype(jnp.uint32),
                      jnp.asarray(served).astype(jnp.uint32)])
    pad = RANGE_RESP_WORDS - 3 - OBS_ROW_WORDS
    return jnp.concatenate([head, row_words.astype(jnp.uint32),
                            jnp.zeros((pad,), jnp.uint32)])


def serve_table_row(table, row_id, want):
    """Serve one (OBS_ROW_WORDS,)-padded row of a small device table
    (histogram / drop-reason counts).  Snapshot semantics: no request
    buffer — the caller reads whatever the table held at batch ingress,
    i.e. totals through the previous batch.  Returns (row, served)."""
    rows, width = table.shape
    ok = want & (row_id >= 0) & (row_id < rows)
    row = table[jnp.clip(row_id, 0, rows - 1)].astype(jnp.uint32)
    row = jnp.where(ok, row, jnp.zeros_like(row))
    if width < OBS_ROW_WORDS:
        row = jnp.concatenate(
            [row, jnp.zeros((OBS_ROW_WORDS - width,), jnp.uint32)])
    else:
        row = row[:OBS_ROW_WORDS]
    served = jnp.where(ok, OBS_ROW_WORDS, 0)
    return row, served


def serve_group_row(healthy, served, want):
    """Serve one dispatch group's state in the wide-response layout:
    [n_replicas, healthy bitmap, per-replica served counters...] padded
    to OBS_ROW_WORDS.  ``healthy`` is (N,) bool, ``served`` (N,) int32.
    Returns (row, served_word_count)."""
    n = healthy.shape[0]
    bitmap = jnp.sum(healthy.astype(jnp.uint32)
                     << jnp.arange(n, dtype=jnp.uint32))
    k = min(n, OBS_ROW_WORDS - 2)
    row = jnp.concatenate([
        jnp.stack([jnp.full((), n, jnp.uint32), bitmap]),
        served[:k].astype(jnp.uint32),
        jnp.zeros((OBS_ROW_WORDS - 2 - k,), jnp.uint32)])
    row = jnp.where(want, row, jnp.zeros_like(row))
    return row, jnp.where(want, 2 + k, 0)


def serve_series_row(ring, wr, win_len, age, node, want):
    """Serve one (node, window) cell block of the time-series ring
    (repro.obs.series) in the wide-response layout.  ``age`` counts back
    from the newest *completed* window (0 = newest).  Snapshot
    semantics, same staleness window as HISTO_READ.  Returns
    ((OBS_ROW_WORDS,) row, served): [windows_closed, window_len,
    metric deltas...]."""
    W, N, M = ring.shape
    written = jnp.minimum(wr, W)
    ok = (want & (age >= 0) & (age < written)
          & (node >= 0) & (node < N))
    slot = jnp.mod(wr - 1 - jnp.clip(age, 0, W - 1), W)
    cell = ring[slot, jnp.clip(node, 0, N - 1)].astype(jnp.uint32)
    row = jnp.concatenate([
        jnp.stack([wr.astype(jnp.uint32), win_len.astype(jnp.uint32)]),
        cell,
        jnp.zeros((OBS_ROW_WORDS - 2 - M,), jnp.uint32)])
    row = jnp.where(ok, row, jnp.zeros_like(row))
    served = jnp.where(ok, 2 + M, 0)
    return row, served


def serve_log_read_range(entries, wrs, fills, log_id, start, count, want):
    """Serve one LOG_READ_RANGE: up to MAX_RANGE consecutive entries of
    one log, newest-first from age ``start``, in a single response frame.

    Returns (fills', rows (MAX_RANGE, ROW_WORDS) uint32, served) where
    ``served`` is the number of valid rows (0 = dropped or empty).  The
    whole range occupies ONE request-buffer slot — bulk streaming is the
    point: 1 frame replaces up to MAX_RANGE one-row round trips."""
    t, n, _ = entries.shape
    li = jnp.clip(log_id, 0, t - 1)
    in_range = (log_id >= 0) & (log_id < t)
    accepted = want & in_range & (fills[li] < telemetry.REQ_BUF)
    fills = fills.at[li].add(accepted.astype(jnp.int32))
    written = jnp.minimum(wrs[li], n)
    avail = jnp.maximum(written - jnp.maximum(start, 0), 0)
    served = jnp.where(accepted,
                       jnp.clip(count, 0, jnp.minimum(avail, MAX_RANGE)), 0)
    ages = jnp.maximum(start, 0) + jnp.arange(MAX_RANGE)
    eidx = jnp.mod(wrs[li] - 1 - ages, n)
    rows = entries[li, eidx][:, :ROW_WORDS].astype(jnp.uint32)
    rows = jnp.where((jnp.arange(MAX_RANGE) < served)[:, None], rows, 0)
    return fills, rows, served


def serve_log_read(entries, wrs, fills, log_id, age, want):
    """Serve one LOG_READ against the stacked per-tile RingLogs.

    entries: (T, N, LOG_WIDTH) int32, wrs: (T,) int32 write counters,
    fills: (T,) int32 request-buffer fills.  Returns (fills', row, accepted)
    where row is the (5,) uint32 counter prefix [step, packets_in, drops,
    noc_latency, tile_index].  A request finding its log's REQ_BUF full is
    dropped (accepted=False) — the client re-requests, paper semantics."""
    t, n, _ = entries.shape
    li = jnp.clip(log_id, 0, t - 1)
    in_range = (log_id >= 0) & (log_id < t)
    accepted = want & in_range & (fills[li] < telemetry.REQ_BUF)
    fills = fills.at[li].add(accepted.astype(jnp.int32))
    eidx = jnp.mod(wrs[li] - 1 - age, n)
    row = entries[li, eidx][:5].astype(jnp.uint32)
    row = jnp.where(accepted, row, jnp.zeros_like(row))
    return fills, row, accepted
