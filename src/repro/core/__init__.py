"""Beehive core: tile/NoC substrate, routing, deadlock analysis, scale-out,
control plane, telemetry."""
from repro.core.message import PacketBatch, make_batch
from repro.core.noc import (Channel, chain_channels, chain_latency_ns,
                            dor_path, link_bandwidth_gbps)
from repro.core.topology import RouteEntry, TileDecl, TopologyConfig
from repro.core.deadlock import DeadlockReport, analyze, assert_deadlock_free
from repro.core.routing import DROP, RouteTable, flow_hash, make_table
from repro.core.tile import StackRuntime, TERMINAL, Tile
from repro.core.compiler import (CompileError, CompiledPipeline,
                                 StackCompiler, register_tile)
from repro.core import control, scaleout, telemetry
