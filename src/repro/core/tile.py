"""Tile runtime: a tile = declaration (placement) + processing fn + state.

The processing fn is pure JAX: (state, PacketBatch, active_mask) ->
(state, PacketBatch, next_loc).  `active_mask` selects the packets
currently located at this tile; the fn must leave other packets untouched
(the helpers here do the masking).  State holds routing tables, protocol
state machines, logs — everything the control plane may rewrite at runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.message import PacketBatch
from repro.core.routing import DROP, RouteTable
from repro.core.topology import TileDecl, TopologyConfig
from repro.core import deadlock

ProcessFn = Callable[[Any, PacketBatch, jnp.ndarray],
                     "tuple[Any, PacketBatch, jnp.ndarray]"]


@dataclasses.dataclass
class Tile:
    decl: TileDecl
    process: ProcessFn
    state: Any


def masked_update(mask, new, old):
    """Broadcast-select along the batch dim for arbitrary-rank tensors."""
    m = mask.reshape((-1,) + (1,) * (new.ndim - 1))
    return jnp.where(m, new, old)


def route_by(table: RouteTable, field, mask, old_loc):
    nxt = table.lookup(field)
    return jnp.where(mask, nxt, old_loc)


class StackRuntime:
    """Executes a Beehive topology on packet batches.

    Build time ("FPGA image build"): validates the topology, runs the
    compile-time deadlock analysis, freezes tile ids.  Run time: packets
    carry their current tile id; each round every tile processes the
    packets located at it and forwards them per its routing table
    (node-table routing).  The whole thing is one jittable function of
    (state, batch).
    """

    def __init__(self, topo: TopologyConfig, tiles: Dict[str, Tile],
                 max_hops: Optional[int] = None,
                 check_deadlock: bool = True):
        errs = topo.validate()
        if errs:
            raise ValueError("invalid topology:\n" + "\n".join(errs))
        if check_deadlock:
            deadlock.assert_deadlock_free(topo)
        self.topo = topo
        self.order = [t.name for t in topo.tiles]
        self.tile_ids = {n: i for i, n in enumerate(self.order)}
        self.tiles = tiles
        longest = max((len(c) for c in topo.chains), default=4)
        self.max_hops = max_hops or longest + 2

    # ---- state ----------------------------------------------------------
    def init_state(self) -> Dict[str, Any]:
        return {n: self.tiles[n].state for n in self.order if n in self.tiles}

    def id_of(self, name: str) -> int:
        return self.tile_ids[name]

    # ---- execution ------------------------------------------------------
    def step(self, state: Dict[str, Any], batch: PacketBatch):
        """One routing round: every tile processes its resident packets."""
        new_state = dict(state)
        for name in self.order:
            tile = self.tiles.get(name)
            if tile is None:       # auto-generated empty router tile
                continue
            mask = batch.valid & (batch.loc == self.tile_ids[name])
            st = new_state.get(name)
            st, batch, new_loc = tile.process(st, batch, mask)
            new_state[name] = st
            batch = dataclasses.replace(
                batch,
                loc=jnp.where(mask, new_loc, batch.loc),
                valid=batch.valid & (jnp.where(mask, new_loc, 0) != DROP))
        return new_state, batch

    def run(self, state: Dict[str, Any], batch: PacketBatch):
        """Run rounds until every chain has completed (max_hops rounds)."""
        for _ in range(self.max_hops):
            state, batch = self.step(state, batch)
        return state, batch


TERMINAL = 10_000  # loc for packets parked at an app/egress endpoint


def park(mask, old_loc, park_id: int = TERMINAL):
    """Next-loc for tiles that consume packets (apps, egress)."""
    return jnp.where(mask, jnp.int32(park_id), old_loc)
