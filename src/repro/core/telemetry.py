"""Telemetry / logging tiles (paper §4.6).

Tiles keep fixed-size ring-buffer logs in their state (cycle timestamp +
payload words).  A UDP-based readback protocol serves individual entries:
each log is bound to a UDP port; the read interface keeps a small request
buffer and *drops* requests when full (clients re-request — paper
semantics).  TCP header logs record entry/exit timestamps so an external
replay harness can drive cycle-accurate re-execution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

LOG_WIDTH = 8          # int32 words per entry
REQ_BUF = 4            # outstanding readback requests
PIPE_LOG_ENTRIES = 64  # ring depth of every compiled-pipeline log (logs
                       # served together over LOG_READ must share a depth)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RingLog:
    entries: jnp.ndarray      # (N, LOG_WIDTH) int32
    wr: jnp.ndarray           # () int32 — total writes (head = wr % N)
    req_fill: jnp.ndarray     # () int32 — outstanding readback requests


def make_log(n_entries: int = 256) -> RingLog:
    return RingLog(
        entries=jnp.zeros((n_entries, LOG_WIDTH), jnp.int32),
        wr=jnp.zeros((), jnp.int32),
        req_fill=jnp.zeros((), jnp.int32),
    )


def append(log: RingLog, rows: jnp.ndarray, mask: jnp.ndarray) -> RingLog:
    """Append masked rows (B, W); timestamps already in col 0.  W is the
    log's own entry width (LOG_WIDTH for counter logs; the flight
    recorder's wider trace rows reuse the same fused masked scatter)."""
    n, w = log.entries.shape
    order = jnp.cumsum(mask.astype(jnp.int32)) - 1
    slots = (log.wr + order) % n
    slots = jnp.where(mask, slots, n)          # parked writes -> OOB row
    padded = jnp.concatenate(
        [log.entries, jnp.zeros((1, w), jnp.int32)], axis=0)
    padded = padded.at[slots].set(rows)
    return dataclasses.replace(
        log, entries=padded[:n], wr=log.wr + mask.sum())


def read_entry(log: RingLog, idx) -> Tuple[RingLog, jnp.ndarray, jnp.ndarray]:
    """Serve one readback request.  Returns (log', entry, accepted).

    An accepted request occupies one request-buffer slot until the service
    completes (:func:`drain`); requests arriving with the buffer full are
    dropped (accepted=False) and the client re-requests — paper §4.6."""
    n = log.entries.shape[0]
    accepted = log.req_fill < REQ_BUF
    log = dataclasses.replace(
        log, req_fill=log.req_fill + accepted.astype(jnp.int32))
    entry = log.entries[idx % n]
    return log, entry, accepted


def drain(log: RingLog, served=None) -> RingLog:
    """Service completion: `served` outstanding requests (default: all)
    leave the request buffer, freeing slots for new readbacks."""
    served = log.req_fill if served is None else served
    return dataclasses.replace(
        log, req_fill=jnp.maximum(log.req_fill - served, 0))


def entry_at(log: RingLog, age) -> jnp.ndarray:
    """The entry written `age` appends ago (0 = newest)."""
    cap = log.entries.shape[0]
    return log.entries[(log.wr - 1 - age) % cap]


def timestamp(step_counter) -> jnp.ndarray:
    """Cycle-timestamp analog: the runtime's step counter."""
    return step_counter.astype(jnp.int32)


# ---- per-tile pipeline counters (compiled-executor diagnostics) -----------
# Row layout: [step, packets_in, drops, noc_latency_cycles, tile_index, 0..]


def counter_row(step, pkts_in, drops, lat_cycles, tile_index) -> jnp.ndarray:
    """One (1, LOG_WIDTH) counter entry for a tile's RingLog."""
    row = jnp.stack([
        timestamp(step),
        jnp.asarray(pkts_in, jnp.int32),
        jnp.asarray(drops, jnp.int32),
        jnp.asarray(lat_cycles, jnp.int32),
        jnp.asarray(tile_index, jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
        jnp.zeros((), jnp.int32),
    ])
    return row[None, :]


# ---- stacked node log: every pipeline node's counters in ONE RingLog ------
# The executor's per-batch telemetry is a single (num_nodes, LOG_WIDTH) row
# write into a RingLog whose entries are (depth, num_nodes, LOG_WIDTH) —
# one scatter per batch for the whole pipeline instead of the masked
# cumsum/concat/scatter machinery once per stage.  `req_fill` is per node
# ((num_nodes,)) so LOG_READ backpressure stays per log id.


def make_node_log(num_nodes: int,
                  n_entries: int = PIPE_LOG_ENTRIES) -> RingLog:
    return RingLog(
        entries=jnp.zeros((n_entries, num_nodes, LOG_WIDTH), jnp.int32),
        wr=jnp.zeros((), jnp.int32),
        req_fill=jnp.zeros((num_nodes,), jnp.int32),
    )


def append_stacked(log: RingLog, rows: jnp.ndarray) -> RingLog:
    """Append one (num_nodes, LOG_WIDTH) row block — a single scatter."""
    n = log.entries.shape[0]
    return dataclasses.replace(
        log, entries=log.entries.at[log.wr % n].set(rows), wr=log.wr + 1)


def counter_rows(step, pkts_in, drops, lat_cycles,
                 tile_index) -> jnp.ndarray:
    """The whole pipeline's counter block: (num_nodes, LOG_WIDTH) from
    per-node (num_nodes,) columns."""
    n = pkts_in.shape[0]
    return jnp.stack([
        jnp.broadcast_to(timestamp(step), (n,)),
        pkts_in.astype(jnp.int32),
        drops.astype(jnp.int32),
        lat_cycles.astype(jnp.int32),
        tile_index.astype(jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
    ], axis=1)


# ---- drop-reason attribution (repro.obs.reasons codes) --------------------
# One (num_nodes, num_reasons) int32 table in telemetry state; the executor
# folds every stage's attributed drops into it with ONE add per batch.


def make_drop_table(num_nodes: int, num_reasons: int) -> jnp.ndarray:
    return jnp.zeros((num_nodes, num_reasons), jnp.int32)


def reason_counts(reason: jnp.ndarray, counted: jnp.ndarray,
                  num_reasons: int) -> jnp.ndarray:
    """One node's (num_reasons,) counts for one batch: `reason` (B,)
    int32 codes, `counted` (B,) bool (which rows to attribute)."""
    hot = (reason[:, None] == jnp.arange(num_reasons)[None, :]) \
        & counted[:, None]
    return hot.sum(axis=0, dtype=jnp.int32)


def node_view(log: RingLog, index: int) -> RingLog:
    """One node's slice of the stacked log as an ordinary RingLog, so
    `latest` / `entry_at` / host-side readers work unchanged."""
    return RingLog(entries=log.entries[:, index, :], wr=log.wr,
                   req_fill=log.req_fill[index])


def latest(log: RingLog, n: int = 1) -> jnp.ndarray:
    """The last n entries, oldest first (readback convenience)."""
    cap = log.entries.shape[0]
    idx = (log.wr - jnp.arange(n, 0, -1)) % cap
    return log.entries[idx]


def log_order(pipe_order, extra_names):
    """The canonical log-id namespace shared by the management tile and
    the operator console: pipeline nodes (in execution order) first, then
    any extra logs (e.g. the per-connection ``tcp_cc.*`` CC logs) sorted
    by name.  A node's log id therefore equals its node index, keeping
    LOG_READ ids stable when extra logs appear.  Node counters live in the
    stacked node log (`make_node_log`); `extra_names` are the keys of
    ``telemetry["logs"]`` (tile-contributed per-object RingLogs)."""
    extra = sorted(n for n in extra_names if n not in pipe_order)
    return list(pipe_order) + extra
