"""Network-on-chip model: 2-D mesh geometry, dimension-ordered (X-then-Y)
wormhole routing, and the message/flit cost model.

This is the structural substrate of Beehive (paper §3.1, §4.1): tiles sit at
(x, y) coordinates; messages traverse router-to-router channels computed by
deterministic DOR.  The JAX runtime moves *batches* in one shot, but every
chain declared by a topology is validated against this model (deadlock
analysis, latency/bandwidth projections), exactly like the paper's
compile-time tooling.

Cost-model constants follow the paper's prototype: 512-bit flits at 250 MHz
(OpenPiton-derived mesh on the Alveo U200), one header flit per message,
per-hop router latency of 2 cycles.  The paper measures 368 ns (92 cycles)
through the full UDP RX+TX chain.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Sequence, Tuple

Coord = Tuple[int, int]

FLIT_BITS = 512
CLOCK_HZ = 250e6
ROUTER_HOP_CYCLES = 2
TILE_PROC_CYCLES = 10          # parse/strip/construct per protocol tile
MAX_NOC_PAYLOAD = 256 * 2**20  # 256 MiB (paper §4.1)


@dataclasses.dataclass(frozen=True)
class Channel:
    """A directed router-to-router link (or injection/ejection port)."""
    src: Coord
    dst: Coord

    def __repr__(self):
        return f"{self.src}->{self.dst}"


def dor_path(src: Coord, dst: Coord) -> List[Channel]:
    """Dimension-ordered (X then Y) route between two routers."""
    path: List[Channel] = []
    x, y = src
    while x != dst[0]:
        nx = x + (1 if dst[0] > x else -1)
        path.append(Channel((x, y), (nx, y)))
        x = nx
    while y != dst[1]:
        ny = y + (1 if dst[1] > y else -1)
        path.append(Channel((x, y), (x, ny)))
        y = ny
    return path


def chain_channels(coords: Sequence[Coord]) -> List[Channel]:
    """All channels acquired, in order, by a message chain across tiles.

    Wormhole streaming means a chain holds its channels in acquisition
    order; a chain that must re-acquire an earlier channel deadlocks
    against itself or a peer (paper Fig. 5)."""
    out: List[Channel] = []
    for a, b in zip(coords, coords[1:]):
        out.extend(dor_path(a, b))
    return out


def flits_for(payload_bytes: int) -> int:
    body = -(-payload_bytes * 8 // FLIT_BITS)
    return 1 + body  # header flit + body flits


def chain_latency_cycles(coords: Sequence[Coord], payload_bytes: int) -> int:
    """Cut-through latency of a message chain (cycles): per-hop router
    latency + per-tile processing + serialization of the message tail."""
    hops = len(chain_channels(coords))
    tiles = len(coords)
    return (hops * ROUTER_HOP_CYCLES + tiles * TILE_PROC_CYCLES
            + flits_for(payload_bytes))


def chain_latency_ns(coords: Sequence[Coord], payload_bytes: int) -> float:
    return chain_latency_cycles(coords, payload_bytes) / CLOCK_HZ * 1e9


def link_bandwidth_gbps() -> float:
    return FLIT_BITS * CLOCK_HZ / 1e9  # 128 Gbps per mesh link


def mesh_coords(dim_x: int, dim_y: int) -> Iterator[Coord]:
    for y in range(dim_y):
        for x in range(dim_x):
            yield (x, y)
