"""Topology configuration — the Python analog of Beehive's XML tooling
(paper §4.7).

A TopologyConfig declares the mesh dimensions, every tile endpoint (name,
coordinates, kind), the next-hop routing entries for each tile, and the set
of message chains the stack supports.  From it we:

  * validate coordinates (unique, in-bounds — the paper's soundness checks),
  * auto-generate empty router-only tiles to keep the mesh rectangular,
  * generate the "top-level wiring" (router adjacency — the paper emits
    SystemVerilog; we emit the adjacency structure the runtime + analysis
    consume),
  * enumerate all possible message chains for compile-time deadlock
    analysis (core/deadlock.py),
  * count configuration LoC for the flexibility benchmark (paper Table 1).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.noc import Coord, chain_channels, mesh_coords

# route-match spaces a tile can use to pick the next hop (paper §4.2: CAMs
# keyed on header fields, runtime-rewritable).  "tile" addresses a
# management-NoC endpoint by its target index (paper §3.6).  "rpc_msg"
# dispatches on the RPC frame's msg_type — app tiles are addressed by the
# request kind, not just the UDP port (the direct-attached serving path).
MATCH_SPACES = ("ethertype", "ip_proto", "udp_port", "tcp_port", "rpc_msg",
                "flow_hash", "rr", "const", "vip", "tile")


@dataclasses.dataclass
class RouteEntry:
    match: str                      # one of MATCH_SPACES
    key: Optional[int]              # None = wildcard/default
    next_tile: str


@dataclasses.dataclass
class TileDecl:
    name: str
    kind: str                       # e.g. "eth_rx", "udp_tx", "app:echo"
    x: int
    y: int
    noc: str = "data"               # "data" | "ctrl"  (paper §3.6)
    routes: List[RouteEntry] = dataclasses.field(default_factory=list)
    # per-tile configuration knobs (the paper's per-element XML attributes;
    # e.g. cc_policy on tcp_rx) — read by the tile's init hook at compile
    params: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def coord(self) -> Coord:
        return (self.x, self.y)


@dataclasses.dataclass
class TopologyConfig:
    name: str
    dim_x: int
    dim_y: int
    tiles: List[TileDecl] = dataclasses.field(default_factory=list)
    chains: List[List[str]] = dataclasses.field(default_factory=list)
    # replica groups registered by core.scaleout.replicate: group name ->
    # {"members": [...], "policy": ..., "kind": ..., "base_port": ...,
    #  "noc": ...}.  A group name is a valid route *target* (the upstream
    # CAM keeps its pre-replication entry); the compiler lowers the group
    # to one RSS dispatch stage.  Group names are NOT tiles: tile()/
    # has_tile() stay strict, has_node()/members_of() resolve both.
    replica_groups: Dict[str, Dict] = dataclasses.field(default_factory=dict)

    # ---- construction helpers (the "XML" the user writes) -----------------
    def add_tile(self, name: str, kind: str, x: int, y: int,
                 noc: str = "data", params: Optional[Dict] = None) -> TileDecl:
        t = TileDecl(name, kind, x, y, noc, params=dict(params or {}))
        self.tiles.append(t)
        return t

    def add_route(self, tile: str, match: str, key: Optional[int],
                  next_tile: str) -> None:
        assert match in MATCH_SPACES, match
        for nm in self.members_of(tile):
            self.tile(nm).routes.append(RouteEntry(match, key, next_tile))

    def add_chain(self, *names: str) -> None:
        # a replica-group name in a chain expands to one chain per member
        # (same treatment replicate() applies to pre-existing chains)
        expanded: List[List[str]] = [[]]
        for n in names:
            members = self.members_of(n)
            expanded = [c + [m] for c in expanded for m in members]
        self.chains.extend(expanded)

    def insert_on_path(self, name: str, kind: str, x: int, y: int,
                       src: str, dst: str, noc: str = "data",
                       match: Optional[str] = None,
                       key: Optional[int] = None) -> TileDecl:
        """Insert a tile between `src` and `dst` purely as a config edit
        (the paper's Table-1 flexibility story): every route on `src` that
        pointed at `dst` is re-aimed at the new tile, the new tile gets a
        const route on to `dst`, and declared chains passing src->dst are
        re-threaded through the new tile so the deadlock analysis stays
        honest.  Neither endpoint's tile function is touched.

        Pass `match`/`key` to rewrite the re-aimed routes' match condition
        — an encapsulation tile classifies on the *outer* header (e.g.
        ip_proto=4 for IP-in-IP), not on the key the original route used."""
        t = self.add_tile(name, kind, x, y, noc)
        src_names = set(self.members_of(src))
        dst_names = {dst} | set(self.members_of(dst))
        for nm in src_names:
            for r in self.tile(nm).routes:
                if r.next_tile in dst_names:
                    r.next_tile = name
                    if match is not None:
                        assert match in MATCH_SPACES, match
                        r.match, r.key = match, key
        t.routes.append(RouteEntry("const", None, dst))
        for c in self.chains:
            for i in range(len(c) - 1):
                if c[i] in src_names and c[i + 1] in dst_names:
                    c.insert(i + 1, name)
                    break
        return t

    # ---- lookups -----------------------------------------------------------
    def tile(self, name: str) -> TileDecl:
        for t in self.tiles:
            if t.name == name:
                return t
        raise KeyError(f"no tile named {name!r}")

    def has_tile(self, name: str) -> bool:
        return any(t.name == name for t in self.tiles)

    def is_replica_group(self, name: str) -> bool:
        return name in self.replica_groups

    def has_node(self, name: str) -> bool:
        """True for a declared tile OR a registered replica group."""
        return self.has_tile(name) or name in self.replica_groups

    def members_of(self, name: str) -> List[str]:
        """A replica group's member tile names; [name] for a plain tile."""
        g = self.replica_groups.get(name)
        return list(g["members"]) if g is not None else [name]

    def routes_of(self, name: str) -> List[RouteEntry]:
        """A tile's routes, or a replica group's (the members carry
        identical clones — the first member's list is the group's)."""
        return self.tile(self.members_of(name)[0]).routes

    def coords_of(self, chain: Sequence[str]) -> List[Coord]:
        return [self.tile(n).coord for n in chain]

    def tiles_on(self, noc: str) -> List[TileDecl]:
        return [t for t in self.tiles if t.noc == noc]

    # ---- validation (paper: coordinate soundness checks) -------------------
    def validate(self) -> List[str]:
        errors: List[str] = []
        seen: Dict[Tuple[str, Coord], str] = {}
        names = set()
        for t in self.tiles:
            if t.name in names:
                errors.append(f"duplicate tile name {t.name!r}")
            names.add(t.name)
            if not (0 <= t.x < self.dim_x and 0 <= t.y < self.dim_y):
                errors.append(f"tile {t.name!r} at {t.coord} outside "
                              f"{self.dim_x}x{self.dim_y} mesh")
            key = (t.noc, t.coord)
            if key in seen:
                errors.append(f"tiles {seen[key]!r} and {t.name!r} share "
                              f"coordinate {t.coord} on noc {t.noc!r}")
            seen[key] = t.name
        for c in self.chains:
            for n in c:
                if n not in names:
                    errors.append(f"chain {c} references unknown tile {n!r}")
        noc_of = {t.name: t.noc for t in self.tiles}
        for gname, g in self.replica_groups.items():
            if gname in names:
                errors.append(f"replica group {gname!r} collides with a "
                              f"declared tile name")
            if not g.get("members"):
                errors.append(f"replica group {gname!r} has no members")
            for m in g.get("members", []):
                if m not in names:
                    errors.append(f"replica group {gname!r} member {m!r} "
                                  f"is not a declared tile")
            # a route aimed at the group resolves to its members' noc
            noc_of[gname] = g.get("noc", "data")
        for t in self.tiles:
            for r in t.routes:
                if r.next_tile not in noc_of:
                    errors.append(f"route on {t.name!r} -> unknown tile "
                                  f"{r.next_tile!r}")
                elif noc_of[r.next_tile] != t.noc:
                    # paper §3.6: management traffic runs on its own NoC so
                    # it never enters a dataplane chain's dependency graph
                    errors.append(
                        f"route on {t.name!r} (noc {t.noc!r}) crosses into "
                        f"noc {noc_of[r.next_tile]!r} tile "
                        f"{r.next_tile!r}: control and data traffic must "
                        f"not share chains")
        for c in self.chains:
            nocs = sorted({noc_of[n] for n in c if n in noc_of})
            if len(nocs) > 1:
                errors.append(f"chain {c} mixes nocs {nocs}")
        return errors

    # ---- generation ("top-level wiring") ------------------------------------
    def filled_coords(self, noc: str = "data") -> List[Coord]:
        """Rectangular mesh = declared tiles + auto-generated empty routers
        (paper: 'automatically generate empty tiles that just contain a
        router')."""
        used = {t.coord for t in self.tiles_on(noc)}
        return [c for c in mesh_coords(self.dim_x, self.dim_y)
                if c not in used]

    def wiring(self, noc: str = "data") -> List[Tuple[Coord, Coord]]:
        """Full-duplex router adjacency for the rectangular mesh."""
        wires = []
        for (x, y) in mesh_coords(self.dim_x, self.dim_y):
            if x + 1 < self.dim_x:
                wires.append(((x, y), (x + 1, y)))
            if y + 1 < self.dim_y:
                wires.append(((x, y), (x, y + 1)))
        return wires

    def chain_channel_lists(self):
        """(chain, ordered channel list) for the deadlock analysis."""
        return [(c, chain_channels(self.coords_of(c))) for c in self.chains]

    # ---- (de)serialization + LoC accounting ---------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name, "dim_x": self.dim_x, "dim_y": self.dim_y,
            "tiles": [{
                "name": t.name, "kind": t.kind, "x": t.x, "y": t.y,
                "noc": t.noc,
                **({"params": dict(t.params)} if t.params else {}),
                "routes": [dataclasses.asdict(r) for r in t.routes],
            } for t in self.tiles],
            "chains": self.chains,
            **({"replica_groups": {g: dict(v) for g, v
                                   in self.replica_groups.items()}}
               if self.replica_groups else {}),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "TopologyConfig":
        topo = cls(d["name"], d["dim_x"], d["dim_y"])
        for td in d["tiles"]:
            t = topo.add_tile(td["name"], td["kind"], td["x"], td["y"],
                              td.get("noc", "data"), td.get("params"))
            for r in td.get("routes", []):
                t.routes.append(RouteEntry(r["match"], r["key"],
                                           r["next_tile"]))
        topo.chains = [list(c) for c in d.get("chains", [])]
        topo.replica_groups = {g: dict(v) for g, v
                               in d.get("replica_groups", {}).items()}
        return topo

    def config_loc(self, tile_names: Sequence[str]) -> int:
        """Lines of serialized configuration needed to declare the given
        tiles + their route entries — the paper's Table 1 flexibility
        metric."""
        d = self.to_dict()
        lines = 0
        for td in d["tiles"]:
            if td["name"] in tile_names:
                lines += len(json.dumps(td, indent=1).splitlines())
        # destination entries added on *other* tiles
        for td in d["tiles"]:
            if td["name"] in tile_names:
                continue
            for r in td["routes"]:
                if r["next_tile"] in tile_names:
                    lines += 1
        return lines
