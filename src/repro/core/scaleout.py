"""Independent scale-out of tiles + load balancing (paper §3.2, §4.2, §5).

`replicate` clones a declared tile N times at given coordinates and wires a
dispatch policy in front of them:

  round_robin  — stateless services (Reed-Solomon encoder, echo)
  flow_hash    — per-flow state (TCP engines): FNV-1a(4-tuple) mod N keeps
                 a flow pinned to one replica
  port_match   — shard-keyed services (VR witness): dst port -> replica

The dispatch lives in the *upstream* tile's routing step, exactly like the
paper's optional hash tables inside protocol tiles; the hash table is
runtime state, so the control plane can re-balance (or route around a dead
replica) without rebuilding anything.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.routing import flow_hash
from repro.core.topology import TopologyConfig


def replicate(topo: TopologyConfig, base_name: str, n: int,
              coords: Sequence[Tuple[int, int]],
              policy: str = "round_robin") -> List[str]:
    """Clone tile `base_name` into n replicas (config-level operation).
    Returns the replica names.  Chains referencing the base tile are
    expanded to cover every replica (for deadlock analysis)."""
    assert len(coords) == n
    base = topo.tile(base_name)
    names = []
    for i, (x, y) in enumerate(coords):
        nm = f"{base_name}.{i}"
        t = topo.add_tile(nm, base.kind, x, y, base.noc)
        t.routes = list(base.routes)
        names.append(nm)
    # expand chains: every chain through base becomes n chains
    new_chains = []
    for c in topo.chains:
        if base_name in c:
            for nm in names:
                new_chains.append([nm if x == base_name else x for x in c])
        else:
            new_chains.append(c)
    topo.chains = new_chains
    topo.tiles = [t for t in topo.tiles if t.name != base_name]
    return names


# ---------------------------------------------------------------------------
# dispatch policies (vectorized over the packet batch)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DispatchState:
    replica_ids: jnp.ndarray    # (N,) int32 tile ids
    healthy: jnp.ndarray        # (N,) bool — control plane can mark down
    rr_counter: jnp.ndarray     # () int32


def make_dispatch(replica_tile_ids: Sequence[int]) -> DispatchState:
    n = len(replica_tile_ids)
    return DispatchState(
        replica_ids=jnp.asarray(replica_tile_ids, jnp.int32),
        healthy=jnp.ones((n,), bool),
        rr_counter=jnp.zeros((), jnp.int32),
    )


def _healthy_pick(d: DispatchState, idx):
    """Remap an index onto healthy replicas only (failure routing)."""
    n = d.replica_ids.shape[0]
    healthy_idx = jnp.cumsum(d.healthy.astype(jnp.int32)) - 1  # rank of each
    n_healthy = jnp.maximum(d.healthy.sum(), 1)
    target_rank = idx % n_healthy
    # first replica whose rank == target_rank and healthy
    match = (healthy_idx[None, :] == target_rank[:, None]) & d.healthy[None, :]
    pick = jnp.argmax(match, axis=1)
    return d.replica_ids[pick]


def round_robin(d: DispatchState, mask) -> Tuple[DispatchState, jnp.ndarray]:
    """Stateless spraying: packet i -> (counter + rank_of_i_in_mask) mod N."""
    order = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = d.rr_counter + jnp.where(mask, order, 0)
    nxt = _healthy_pick(d, idx)
    d = dataclasses.replace(d, rr_counter=d.rr_counter + mask.sum())
    return d, nxt


def by_flow_hash(d: DispatchState, meta) -> jnp.ndarray:
    """Flow-affine: same 4-tuple always lands on the same replica."""
    return _healthy_pick(d, flow_hash(meta).astype(jnp.int32) & 0x7FFFFFFF)


def by_port(d: DispatchState, port, base_port: int) -> jnp.ndarray:
    """Shard-keyed (VR witness): dst_port - base_port indexes the replica."""
    return _healthy_pick(d, (port - base_port).astype(jnp.int32))


def mark_health(d: DispatchState, replica: int, up: bool) -> DispatchState:
    """Control-plane operation: drain or restore one replica."""
    return dataclasses.replace(d, healthy=d.healthy.at[replica].set(up))
