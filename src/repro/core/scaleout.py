"""Independent scale-out of tiles + load balancing (paper §3.2, §4.2, §5).

`replicate` clones a declared tile N times at given coordinates and wires a
dispatch policy in front of them:

  round_robin  — stateless services (Reed-Solomon encoder, echo)
  flow_hash    — per-flow state (TCP engines): FNV-1a(4-tuple) mod N keeps
                 a flow pinned to one replica
  port_match   — shard-keyed services (VR witness): dst port -> replica

The dispatch lives in the *upstream* tile's routing step, exactly like the
paper's optional hash tables inside protocol tiles; the hash table is
runtime state, so the control plane can re-balance (or route around a dead
replica) without rebuilding anything.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.routing import flow_hash
from repro.core.topology import TopologyConfig


def replicate(topo: TopologyConfig, base_name: str, n: int,
              coords: Sequence[Tuple[int, int]],
              policy: str = "round_robin",
              base_port: Optional[int] = None) -> List[str]:
    """Clone tile `base_name` into n replicas (config-level operation).
    Returns the replica names.  Chains referencing the base tile are
    expanded to cover every replica (for deadlock analysis).

    Non-app kinds (udp_rx, rs_serve, tcp_rx, ...) are additionally
    registered as a *replica group* on the topology: upstream routes keep
    targeting `base_name`, which now names the group, and the compiler
    lowers the group to one RSS-style dispatch stage whose policy table
    is runtime state (the control plane drains/restores replicas with no
    retrace).  ``app:*`` tiles keep the pre-existing semantics — they
    collapse into an app group by kind, dispatched via their AppDecl.
    `base_port` is required by the ``port_match`` policy (dst_port -
    base_port indexes the replica)."""
    assert len(coords) == n
    base = topo.tile(base_name)
    names = []
    for i, (x, y) in enumerate(coords):
        nm = f"{base_name}.{i}"
        t = topo.add_tile(nm, base.kind, x, y, base.noc,
                          params=dict(base.params))
        t.routes = [dataclasses.replace(r) for r in base.routes]
        names.append(nm)
    # expand chains: every chain through base becomes n chains
    new_chains = []
    for c in topo.chains:
        if base_name in c:
            for nm in names:
                new_chains.append([nm if x == base_name else x for x in c])
        else:
            new_chains.append(c)
    topo.chains = new_chains
    topo.tiles = [t for t in topo.tiles if t.name != base_name]
    if not base.kind.startswith("app:"):
        topo.replica_groups[base_name] = {
            "members": list(names), "policy": policy, "kind": base.kind,
            "base_port": base_port, "noc": base.noc,
        }
    return names


# ---------------------------------------------------------------------------
# dispatch policies (vectorized over the packet batch)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DispatchState:
    replica_ids: jnp.ndarray    # (N,) int32 tile ids
    healthy: jnp.ndarray        # (N,) bool — control plane can mark down
    rr_counter: jnp.ndarray     # () int32
    served: jnp.ndarray         # (N,) int32 packets dispatched per replica


def make_dispatch(replica_tile_ids: Sequence[int]) -> DispatchState:
    n = len(replica_tile_ids)
    return DispatchState(
        replica_ids=jnp.asarray(replica_tile_ids, jnp.int32),
        healthy=jnp.ones((n,), bool),
        rr_counter=jnp.zeros((), jnp.int32),
        served=jnp.zeros((n,), jnp.int32),
    )


def _healthy_pick(d: DispatchState, idx):
    """Remap an index onto healthy replicas only (failure routing)."""
    n = d.replica_ids.shape[0]
    healthy_idx = jnp.cumsum(d.healthy.astype(jnp.int32)) - 1  # rank of each
    n_healthy = jnp.maximum(d.healthy.sum(), 1)
    target_rank = idx % n_healthy
    # first replica whose rank == target_rank and healthy
    match = (healthy_idx[None, :] == target_rank[:, None]) & d.healthy[None, :]
    pick = jnp.argmax(match, axis=1)
    return d.replica_ids[pick]


def round_robin(d: DispatchState, mask) -> Tuple[DispatchState, jnp.ndarray]:
    """Stateless spraying: packet i -> (counter + rank_of_i_in_mask) mod N."""
    order = jnp.cumsum(mask.astype(jnp.int32)) - 1
    idx = d.rr_counter + jnp.where(mask, order, 0)
    nxt = _healthy_pick(d, idx)
    d = dataclasses.replace(d, rr_counter=d.rr_counter + mask.sum())
    return d, nxt


def by_flow_hash(d: DispatchState, meta) -> jnp.ndarray:
    """Flow-affine: same 4-tuple always lands on the same replica."""
    return _healthy_pick(d, flow_hash(meta).astype(jnp.int32) & 0x7FFFFFFF)


def by_port(d: DispatchState, port, base_port: int) -> jnp.ndarray:
    """Shard-keyed (VR witness): dst_port - base_port indexes the replica."""
    return _healthy_pick(d, (port - base_port).astype(jnp.int32))


def mark_health(d: DispatchState, replica: int, up: bool) -> DispatchState:
    """Control-plane operation: drain or restore one replica."""
    return dataclasses.replace(d, healthy=d.healthy.at[replica].set(up))


def dispatch_lane(d: DispatchState, policy: str, meta, pred,
                  base_port: Optional[int] = None
                  ) -> Tuple[DispatchState, jnp.ndarray]:
    """One RSS dispatch decision per batch row under `policy`: returns
    (d', lane).  Advances rr_counter (round_robin) and bumps the
    per-replica served counters for rows where `pred` holds — the
    accounting the control plane reads back to verify a drain actually
    rebalanced traffic."""
    if policy == "round_robin":
        d, lane = round_robin(d, pred)
    elif policy == "flow_hash":
        lane = by_flow_hash(d, meta)
    elif policy == "port_match":
        lane = by_port(d, meta["dst_port"], base_port)
    else:
        raise ValueError(f"unknown dispatch policy {policy!r}")
    d = dataclasses.replace(
        d, served=d.served.at[lane].add(pred.astype(jnp.int32)))
    return d, lane
