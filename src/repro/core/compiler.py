"""Topology-compiled stack executor.

The paper's stacks are *configurations*: protocol and application elements
are tiles over the NoC, and the processing graph is whatever the declared
routes say — adding NAT to the TCP path, IP-in-IP to the UDP path, or a
new app replica is a topology edit, never a code edit.  This module makes
the Python runtime behave the same way: :class:`StackCompiler` takes any
validated :class:`TopologyConfig` and emits one jittable batch pipeline.

Compilation steps:

  1. tiles are grouped into execution nodes (app replicas — tiles whose
     kind is ``app:<name>`` — collapse into one dispatch group, mirroring
     the paper's scale-out sets);
  2. the route entries define a DAG over nodes; nodes are topologically
     ordered (stable in declaration order, so replica dispatch matches the
     builder's app order);
  3. each node's kind is bound to a *tile function* from the registry
     (``register_tile``); per-tile state threads through one state pytree;
  4. each packet's path is predicated by the route-match fields
     (``ethertype``, ``ip_proto``, ``udp_port``, …): a packet "arrives" at
     a node iff some in-edge's source succeeded on it AND the route key
     matches — the Python analog of the paper's CAM routing, with no
     hardcoded per-protocol branches anywhere;
  5. every node gets a :class:`telemetry.RingLog` in the state pytree and
     the compiled pipeline appends one counter row per batch per node
     (packets-in, drops, a compile-time NoC latency estimate from
     ``noc.chain_latency_cycles``) — diagnostics come for free on every
     path.

Tile function contract::

    @register_tile("my_kind", init=my_init)          # my_init(ctx) -> dict
    def my_tile(state, carrier, pred, ctx):
        ...
        return state, carrier, ok        # ok: (B,) bool or None (all pass)

``state`` is the full stack state dict (tile functions own documented
slices of it: ``conn`` for TCP, ``nat`` for NAT tables, ``dispatch`` /
``apps`` for app groups).  ``carrier`` is the per-batch value dict
(payload/length/meta plus direction-specific keys); functions mutate a
fresh shallow copy provided by the executor.  ``pred`` is the node's
arrival predicate.  ``ctx`` is a :class:`TileContext`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.core import deadlock, routing, telemetry
from repro.core.noc import chain_latency_cycles
from repro.core.topology import RouteEntry, TileDecl, TopologyConfig

# reference payload for the per-tile NoC latency estimate (the paper's
# latency measurement uses 64-byte messages)
REF_PAYLOAD_BYTES = 64


class CompileError(ValueError):
    pass


# ---------------------------------------------------------------------------
# tile-function registry


@dataclasses.dataclass
class TileSpec:
    fn: Callable
    init: Optional[Callable] = None     # (ctx) -> state-dict contribution
    alive: bool = False                 # RX parse tile: pred & ok feeds the
                                        # chain's "alive" mask


TILE_REGISTRY: Dict[str, TileSpec] = {}


def register_tile(kind: str, init: Optional[Callable] = None,
                  alive: bool = False):
    """Decorator binding a tile kind to its jittable tile function.  Pass
    alive=True for RX-side parse tiles whose success gates packet
    validity (their pred & ok becomes carrier['alive'] downstream)."""
    def deco(fn):
        TILE_REGISTRY[kind] = TileSpec(fn=fn, init=init, alive=alive)
        return fn
    return deco


def resolve_kind(kind: str) -> TileSpec:
    """Exact kind first, then the family before ':' (app:echo -> app)."""
    if kind in TILE_REGISTRY:
        return TILE_REGISTRY[kind]
    fam = kind.split(":", 1)[0]
    if fam in TILE_REGISTRY:
        return TILE_REGISTRY[fam]
    raise CompileError(f"no tile function registered for kind {kind!r} "
                       f"(known: {sorted(TILE_REGISTRY)})")


@dataclasses.dataclass
class TileContext:
    name: str                   # node name (tile name / app group name)
    kind: str
    members: List[TileDecl]     # 1 entry for plain tiles, N for app groups
    binding: Any                # e.g. the AppDecl for app groups
    options: Dict[str, Any]     # compiler-level options (local_ip, ...)
    lat_cycles: int             # NoC latency estimate from the ingress
    index: int                  # execution position
    pipe: Any = None            # pipeline-level meta (order/groups/tables) —
                                # management tiles address peers through it


# ---------------------------------------------------------------------------
# route-match predicates (the CAM lookup, paper §4.2)

_MATCH_FIELD = {"ethertype": "ethertype", "ip_proto": "ip_proto",
                "udp_port": "dst_port", "tcp_port": "dst_port"}


def _match_pred(route: RouteEntry, carrier, n):
    """Per-packet bool for one route entry, evaluated on the live meta."""
    field = _MATCH_FIELD.get(route.match)
    if field is None or route.key is None:     # const / rr / flow_hash / vip
        return jnp.ones((n,), bool)            # wildcard: dispatch decides
    return carrier["meta"][field] == route.key


# ---------------------------------------------------------------------------
# nodes + compiler


@dataclasses.dataclass
class _Node:
    name: str
    kind: str
    members: List[TileDecl]
    index: int


def deep_merge(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        if k in dst and isinstance(dst[k], dict) and isinstance(v, dict):
            deep_merge(dst[k], v)
        else:
            dst[k] = v
    return dst


class StackCompiler:
    """Compiles a TopologyConfig into executable pipelines.

    bindings: extra per-node configuration, keyed by node name (the app
    group name for ``app:*`` tiles).  options: stack-level settings read
    by tile init functions (``local_ip``, ``max_conns``, ``nat_entries``,
    ``outer_src``/``outer_dst`` for IP-in-IP, ...).
    """

    def __init__(self, topo: TopologyConfig,
                 bindings: Optional[Dict[str, Any]] = None,
                 options: Optional[Dict[str, Any]] = None,
                 check_deadlock: bool = True,
                 noc: str = "data"):
        errs = topo.validate()
        if errs:
            raise CompileError("invalid topology:\n" + "\n".join(errs))
        if check_deadlock:
            deadlock.assert_deadlock_free(topo)
        self.topo = topo
        self.bindings = bindings or {}
        self.options = options or {}

        # ---- group tiles into nodes -----------------------------------
        self.nodes: Dict[str, _Node] = {}
        self._node_of: Dict[str, str] = {}
        for t in topo.tiles_on(noc):
            nname = (t.kind.split(":", 1)[1] if t.kind.startswith("app:")
                     else t.name)
            node = self.nodes.get(nname)
            if node is None:
                self.nodes[nname] = _Node(nname, t.kind, [t],
                                          len(self.nodes))
            else:
                if node.kind != t.kind:
                    raise CompileError(
                        f"group {nname!r} mixes kinds {node.kind!r} and "
                        f"{t.kind!r}")
                node.members.append(t)
            self._node_of[t.name] = nname

        # ---- route edges between nodes --------------------------------
        self.edges: List[Tuple[str, str, RouteEntry]] = []
        for t in topo.tiles_on(noc):
            for r in t.routes:
                src = self._node_of.get(t.name)
                dst = self._node_of.get(r.next_tile)
                if src is None or dst is None or src == dst:
                    continue                       # intra-group / other noc
                self.edges.append((src, dst, r))

    # ---- ordering --------------------------------------------------------
    def _reachable(self, ingress: str) -> List[str]:
        seen = {ingress}
        frontier = [ingress]
        while frontier:
            cur = frontier.pop()
            for s, d, _ in self.edges:
                if s == cur and d not in seen:
                    seen.add(d)
                    frontier.append(d)
        return sorted(seen, key=lambda n: self.nodes[n].index)

    def _topo_order(self, names: Sequence[str]) -> List[str]:
        names = set(names)
        indeg = {n: 0 for n in names}
        for s, d, _ in self.edges:
            if s in names and d in names:
                indeg[d] += 1
        order: List[str] = []
        ready = sorted([n for n, d in indeg.items() if d == 0],
                       key=lambda n: self.nodes[n].index)
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for s, d, _ in self.edges:
                if s == cur and d in indeg:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        ready.append(d)
            ready.sort(key=lambda n: self.nodes[n].index)
        if len(order) != len(names):
            cyc = sorted(names - set(order))
            raise CompileError(f"route graph has a cycle through {cyc}")
        return order

    def _latency_estimates(self, ingress: str,
                           names: Sequence[str]) -> Dict[str, int]:
        """Compile-time NoC latency (cycles) from the ingress tile to each
        node, along the shortest route-graph path (BFS)."""
        parent: Dict[str, Optional[str]] = {ingress: None}
        frontier = [ingress]
        while frontier:
            nxt = []
            for cur in frontier:
                for s, d, _ in self.edges:
                    if s == cur and d not in parent:
                        parent[d] = cur
                        nxt.append(d)
            frontier = nxt
        out = {}
        for n in names:
            path, cur = [], n
            while cur is not None:
                path.append(cur)
                cur = parent.get(cur)
            coords = [self.nodes[p].members[0].coord for p in reversed(path)]
            out[n] = chain_latency_cycles(coords, REF_PAYLOAD_BYTES)
        return out

    def _is_trunk(self, ingress: str, names, node: str) -> bool:
        """True when every packet path from the ingress passes through
        `node` (route-DAG post-dominance): no sink stays reachable once the
        node is removed.  A trunk alive-tile *gates* the whole stack (its
        pred & ok replaces the alive mask, like the hand-written chains);
        a branch alive-tile only judges the packets routed through it."""
        names = set(names)
        sinks = {n for n in names
                 if not any(s == n and d in names for s, d, _ in self.edges)}
        seen = {ingress} if ingress != node else set()
        frontier = list(seen)
        while frontier:
            cur = frontier.pop()
            for s, d, _ in self.edges:
                if s == cur and d in names and d != node and d not in seen:
                    seen.add(d)
                    frontier.append(d)
        return not (seen & sinks)

    # ---- compilation -----------------------------------------------------
    def compile(self, ingress: str) -> "CompiledPipeline":
        """Pipeline over every node reachable from `ingress` (a tile name)."""
        if ingress not in self._node_of:
            raise CompileError(f"unknown ingress tile {ingress!r}")
        start = self._node_of[ingress]
        names = self._reachable(start)
        order = self._topo_order(names)
        lats = self._latency_estimates(start, names)
        index_of = {n: i for i, n in enumerate(order)}

        # runtime route tables (the paper's runtime-rewritable CAMs): every
        # keyed route entry becomes a slot in a per-(source, match-space)
        # table held in state, so the control plane can rewrite dispatch
        # without recompiling.  Values are execution-node indices.
        table_entries: Dict[str, List[Tuple[int, int]]] = {}
        for s, d, r in self.edges:
            if (s in index_of and d in index_of and r.key is not None
                    and r.match in _MATCH_FIELD):
                table_entries.setdefault(f"{s}:{r.match}", []).append(
                    (r.key, index_of[d]))

        pipe_meta = {
            "order": order,
            "groups": [n for n in order
                       if self.nodes[n].kind.startswith("app:")],
            "tables": sorted(table_entries),
        }

        stages = []
        for i, n in enumerate(order):
            node = self.nodes[n]
            spec = resolve_kind(node.kind)
            binding = self.bindings.get(n, self.bindings.get(node.kind))
            ctx = TileContext(name=n, kind=node.kind, members=node.members,
                              binding=binding, options=self.options,
                              lat_cycles=lats[n], index=i, pipe=pipe_meta)
            in_edges = [(s, r) for s, d, r in self.edges
                        if d == n and s in names]
            trunk = spec.alive and self._is_trunk(start, names, n)
            stages.append((node, spec, ctx, in_edges, trunk))
        return CompiledPipeline(start, stages, table_entries, pipe_meta)


class CompiledPipeline:
    """One jittable executor: run(state, carrier) -> (state, carrier)."""

    def __init__(self, ingress: str, stages, table_entries=None,
                 pipe_meta=None):
        self.ingress = ingress
        self.stages = stages
        self.table_entries = table_entries or {}
        self.pipe_meta = pipe_meta or {"order": self.order, "groups": [],
                                       "tables": []}
        self._index = {node.name: i
                       for i, (node, *_) in enumerate(self.stages)}

    @property
    def order(self) -> List[str]:
        return [node.name for node, *_ in self.stages]

    def summary(self) -> str:
        lines = []
        for node, _, ctx, in_edges, _trunk in self.stages:
            srcs = ", ".join(f"{s}[{r.match}"
                             f"{'' if r.key is None else '=' + hex(r.key)}]"
                             for s, r in in_edges) or "(ingress)"
            lines.append(f"{ctx.index:2d} {node.name:<12} kind={node.kind:<12}"
                         f" lat~{ctx.lat_cycles}cyc <- {srcs}")
        return "\n".join(lines)

    # ---- state -----------------------------------------------------------
    def init_state(self, with_telemetry: bool = True,
                   log_entries: int = telemetry.PIPE_LOG_ENTRIES
                   ) -> Dict[str, Any]:
        st: Dict[str, Any] = {}
        for node, spec, ctx, *_ in self.stages:
            if spec.init is not None:
                deep_merge(st, spec.init(ctx))
        if self.table_entries:
            deep_merge(st, {"routes": {
                t: routing.make_table(ents)
                for t, ents in self.table_entries.items()}})
        if with_telemetry:
            deep_merge(st, {"telemetry": {
                "step": jnp.zeros((), jnp.int32),
                "logs": {node.name: telemetry.make_log(log_entries)
                         for node, *_ in self.stages},
            }})
        # logs served together over LOG_READ are stacked: every log must
        # share one ring depth (tile inits contribute extra logs, e.g.
        # tcp_cc.*, at telemetry.PIPE_LOG_ENTRIES) — reject a mismatch
        # here instead of crashing inside the compiled mgmt tile
        logs = st.get("telemetry", {}).get("logs", {})
        depths = {lg.entries.shape[0] for lg in logs.values()}
        if len(depths) > 1:
            raise ValueError(
                f"telemetry logs mix ring depths {sorted(depths)}; use "
                f"log_entries={telemetry.PIPE_LOG_ENTRIES} "
                f"(telemetry.PIPE_LOG_ENTRIES) when tile-contributed logs "
                f"are present")
        return st

    # ---- execution -------------------------------------------------------
    def run(self, state: Dict[str, Any], carrier: Dict[str, Any]):
        state = dict(state)
        carrier = dict(carrier)
        carrier.setdefault("meta", {})
        carrier.setdefault("info", {})
        n = carrier["payload"].shape[0]

        telem = state.get("telemetry")
        if telem is not None:
            telem = {"step": telem["step"] + 1, "logs": dict(telem["logs"])}
            state["telemetry"] = telem

        routes_rt = state.get("routes")
        ok_of: Dict[str, jnp.ndarray] = {}
        for node, spec, ctx, in_edges, trunk in self.stages:
            if not in_edges:                       # ingress / chain root
                pred = jnp.ones((n,), bool)
            else:
                pred = jnp.zeros((n,), bool)
                for src, route in in_edges:
                    tname = f"{src}:{route.match}"
                    if (route.key is not None and route.match in _MATCH_FIELD
                            and routes_rt is not None
                            and tname in routes_rt):
                        # live CAM lookup: the control plane can rewrite
                        # this table between batches (paper §4.2)
                        field = carrier["meta"][_MATCH_FIELD[route.match]]
                        nxt = routes_rt[tname].lookup(
                            field.astype(jnp.int32))
                        hit = nxt == self._index[node.name]
                    else:
                        hit = _match_pred(route, carrier, n)
                    pred = pred | (ok_of[src] & hit)
            carrier = dict(carrier)
            state, carrier, ok = spec.fn(state, carrier, pred, ctx)
            ok_of[node.name] = pred & ok if ok is not None else pred
            if spec.alive:
                if trunk:      # gates all traffic: alive = arrived & ok
                    carrier["alive"] = ok_of[node.name]
                else:          # branch tile: judge only its own packets
                    prev = carrier.get("alive", jnp.ones((n,), bool))
                    carrier["alive"] = jnp.where(pred, ok_of[node.name],
                                                 prev)
            if telem is not None and node.name in telem["logs"]:
                row = telemetry.counter_row(
                    telem["step"], pred.sum(dtype=jnp.int32),
                    (pred & ~ok_of[node.name]).sum(dtype=jnp.int32),
                    ctx.lat_cycles, ctx.index)
                telem["logs"][node.name] = telemetry.append(
                    telem["logs"][node.name], row, jnp.ones((1,), bool))

        # ---- post-batch table commit (management plane) ------------------
        # A management tile stages table writes in the carrier; they are
        # committed here, after every stage has run, so a command always
        # takes effect on the *next* batch — live reconfiguration with no
        # recompile and no intra-batch ordering hazards (paper §3.6).
        staged = carrier.get("mgmt_staged")
        if staged is not None:
            if staged.get("nat") is not None and "nat" in state:
                state["nat"] = staged["nat"]
            if staged.get("healthy") and "dispatch" in state:
                disp = dict(state["dispatch"])
                for gname, h in staged["healthy"].items():
                    # only the control-owned field: the batch's rr_counter
                    # advances stay intact
                    disp[gname] = dataclasses.replace(disp[gname], healthy=h)
                state["dispatch"] = disp
            if staged.get("routes") is not None:
                state["routes"] = staged["routes"]
            if staged.get("rate") is not None and "rate" in state:
                state["rate"] = staged["rate"]
            if staged.get("cc") is not None and "conn" in state \
                    and "cc" in state["conn"]:
                conn = dict(state["conn"])
                conn["cc"] = staged["cc"]
                state["conn"] = conn
        return state, carrier


# ---------------------------------------------------------------------------
# the generic app-group tile function (dispatch + process, paper §4.2/§5)


def _app_init(ctx: TileContext) -> dict:
    from repro.core.scaleout import make_dispatch
    a = ctx.binding
    if a is None:
        raise CompileError(f"app group {ctx.name!r} has no binding")
    return {"dispatch": {a.name: make_dispatch(list(range(a.n_replicas)))},
            "apps": {a.name: a.state}}


@register_tile("app", init=_app_init)
def _app_group(state, carrier, pred, ctx):
    """Replica dispatch + app processing for one app group.

    `pred` IS the arrival predicate derived from the udp_port route
    entries, so port matching lives in the topology, not here."""
    from repro.core.scaleout import by_flow_hash, by_port, round_robin
    a = ctx.binding
    m = carrier["meta"]
    at_app = pred

    dispatch = dict(state["dispatch"])
    apps = dict(state["apps"])
    d = dispatch[a.name]
    if a.policy == "round_robin":
        d, replica = round_robin(d, at_app)
    elif a.policy == "flow_hash":
        replica = by_flow_hash(d, m)
    else:                                          # port_match
        replica = by_port(d, m["dst_port"], a.port)
    dispatch[a.name] = d

    ast, nb, nl = a.process(apps[a.name], carrier["body"], carrier["blen"],
                            m, at_app, replica)
    apps[a.name] = ast
    state = dict(state)
    state["dispatch"] = dispatch
    state["apps"] = apps

    carrier["out_body"] = jnp.where(at_app[:, None], nb, carrier["out_body"])
    carrier["out_blen"] = jnp.where(at_app, nl, carrier["out_blen"])
    info = dict(carrier["info"])
    info[a.name] = at_app
    carrier["info"] = info
    return state, carrier, None
