"""Topology-compiled stack executor.

The paper's stacks are *configurations*: protocol and application elements
are tiles over the NoC, and the processing graph is whatever the declared
routes say — adding NAT to the TCP path, IP-in-IP to the UDP path, or a
new app replica is a topology edit, never a code edit.  This module makes
the Python runtime behave the same way: :class:`StackCompiler` takes any
validated :class:`TopologyConfig` and emits one jittable batch pipeline.

Compilation steps:

  1. tiles are grouped into execution nodes (app replicas — tiles whose
     kind is ``app:<name>`` — collapse into one dispatch group, mirroring
     the paper's scale-out sets);
  2. the route entries define a DAG over nodes; nodes are topologically
     ordered (stable in declaration order, so replica dispatch matches the
     builder's app order);
  3. each node's kind is bound to a *tile function* from the registry
     (``register_tile``); per-tile state threads through one state pytree;
  4. each packet's path is predicated by the route-match fields
     (``ethertype``, ``ip_proto``, ``udp_port``, …): a packet "arrives" at
     a node iff some in-edge's source succeeded on it AND the route key
     matches — the Python analog of the paper's CAM routing, with no
     hardcoded per-protocol branches anywhere;
  5. every node gets a :class:`telemetry.RingLog` in the state pytree and
     the compiled pipeline appends one counter row per batch per node
     (packets-in, drops, a compile-time NoC latency estimate from
     ``noc.chain_latency_cycles``) — diagnostics come for free on every
     path.

Tile function contract::

    @register_tile("my_kind", init=my_init)          # my_init(ctx) -> dict
    def my_tile(state, carrier, pred, ctx):
        ...
        return state, carrier, ok        # ok: (B,) bool or None (all pass)

``state`` is the full stack state dict (tile functions own documented
slices of it: ``conn`` for TCP, ``nat`` for NAT tables, ``dispatch`` /
``apps`` for app groups).  ``carrier`` is the per-batch value dict
(payload/length/meta plus direction-specific keys); functions mutate a
fresh shallow copy provided by the executor.  ``pred`` is the node's
arrival predicate.  ``ctx`` is a :class:`TileContext`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import deadlock, routing, telemetry
from repro.core.noc import chain_latency_cycles
from repro.core.topology import RouteEntry, TileDecl, TopologyConfig
from repro.obs import flight, postcard, reasons, series, slo

# reference payload for the per-tile NoC latency estimate (the paper's
# latency measurement uses 64-byte messages)
REF_PAYLOAD_BYTES = 64


class CompileError(ValueError):
    pass


# ---------------------------------------------------------------------------
# tile-function registry


@dataclasses.dataclass
class TileSpec:
    fn: Callable
    init: Optional[Callable] = None     # (ctx) -> state-dict contribution
    alive: bool = False                 # RX parse tile: pred & ok feeds the
                                        # chain's "alive" mask
    rewrites: Tuple[str, ...] = ()      # meta fields this kind re-parses
                                        # (pruning soundness: see
                                        # StackCompiler._prune_dead)


TILE_REGISTRY: Dict[str, TileSpec] = {}


def register_tile(kind: str, init: Optional[Callable] = None,
                  alive: bool = False, rewrites: Tuple[str, ...] = ()):
    """Decorator binding a tile kind to its jittable tile function.  Pass
    alive=True for RX-side parse tiles whose success gates packet
    validity (their pred & ok becomes carrier['alive'] downstream).
    `rewrites` names the route-match meta fields the tile (re)writes —
    a duplicated parse tile (the paper's repeated-header pattern) makes
    that field runtime-dependent, which disables dead-stage pruning on
    it."""
    def deco(fn):
        TILE_REGISTRY[kind] = TileSpec(fn=fn, init=init, alive=alive,
                                       rewrites=tuple(rewrites))
        return fn
    return deco


def resolve_kind(kind: str) -> TileSpec:
    """Exact kind first, then the family before ':' (app:echo -> app)."""
    if kind in TILE_REGISTRY:
        return TILE_REGISTRY[kind]
    fam = kind.split(":", 1)[0]
    if fam in TILE_REGISTRY:
        return TILE_REGISTRY[fam]
    raise CompileError(f"no tile function registered for kind {kind!r} "
                       f"(known: {sorted(TILE_REGISTRY)})")


@dataclasses.dataclass
class TileContext:
    name: str                   # node name (tile name / app group name)
    kind: str
    members: List[TileDecl]     # 1 entry for plain tiles, N for app groups
    binding: Any                # e.g. the AppDecl for app groups
    options: Dict[str, Any]     # compiler-level options (local_ip, ...)
    lat_cycles: int             # NoC latency estimate from the ingress
    index: int                  # execution position
    pipe: Any = None            # pipeline-level meta (order/groups/tables) —
                                # management tiles address peers through it


# ---------------------------------------------------------------------------
# route-match predicates (the CAM lookup, paper §4.2)

_MATCH_FIELD = {"ethertype": "ethertype", "ip_proto": "ip_proto",
                "udp_port": "dst_port", "tcp_port": "dst_port",
                "rpc_msg": "msg_type"}


def _match_pred(route: RouteEntry, carrier, n):
    """Per-packet bool for one route entry, evaluated on the live meta."""
    field = _MATCH_FIELD.get(route.match)
    if field is None or route.key is None:     # const / rr / flow_hash / vip
        return jnp.ones((n,), bool)            # wildcard: dispatch decides
    return carrier["meta"][field] == route.key


# ---------------------------------------------------------------------------
# nodes + compiler


@dataclasses.dataclass
class _Node:
    name: str
    kind: str
    members: List[TileDecl]
    index: int


def deep_merge(dst: dict, src: dict) -> dict:
    for k, v in src.items():
        if k in dst and isinstance(dst[k], dict) and isinstance(v, dict):
            deep_merge(dst[k], v)
        else:
            dst[k] = v
    return dst


class StackCompiler:
    """Compiles a TopologyConfig into executable pipelines.

    bindings: extra per-node configuration, keyed by node name (the app
    group name for ``app:*`` tiles).  options: stack-level settings read
    by tile init functions (``local_ip``, ``max_conns``, ``nat_entries``,
    ``outer_src``/``outer_dst`` for IP-in-IP, ...).
    """

    def __init__(self, topo: TopologyConfig,
                 bindings: Optional[Dict[str, Any]] = None,
                 options: Optional[Dict[str, Any]] = None,
                 check_deadlock: bool = True,
                 noc: str = "data"):
        errs = topo.validate()
        if errs:
            raise CompileError("invalid topology:\n" + "\n".join(errs))
        if check_deadlock:
            deadlock.assert_deadlock_free(topo)
        self.topo = topo
        self.bindings = bindings or {}
        self.options = options or {}

        # ---- replica groups (core.scaleout.replicate on non-app kinds) -
        # validated here so an un-lowerable group fails loudly at compiler
        # construction, naming the group — never silently mis-routing
        self._rgroups: Dict[str, Dict] = {}
        member_group: Dict[str, str] = {}
        for gname, g in getattr(topo, "replica_groups", {}).items():
            self._check_replica_group(gname, g)
            if g.get("noc", "data") != noc:
                continue
            self._rgroups[gname] = g
            for m in g["members"]:
                member_group[m] = gname

        # ---- group tiles into nodes -----------------------------------
        self.nodes: Dict[str, _Node] = {}
        self._node_of: Dict[str, str] = {}
        for t in topo.tiles_on(noc):
            if t.kind.startswith("app:"):
                nname = t.kind.split(":", 1)[1]
            else:
                nname = member_group.get(t.name, t.name)
            node = self.nodes.get(nname)
            if node is None:
                self.nodes[nname] = _Node(nname, t.kind, [t],
                                          len(self.nodes))
            else:
                if node.kind != t.kind:
                    raise CompileError(
                        f"group {nname!r} mixes kinds {node.kind!r} and "
                        f"{t.kind!r}")
                node.members.append(t)
            self._node_of[t.name] = nname
        for gname in self._rgroups:
            # upstream CAM entries still target the group name
            self._node_of.setdefault(gname, gname)

        # ---- route edges between nodes --------------------------------
        # replica members carry identical route clones — dedupe so the
        # group node gets each logical edge once (table slots included)
        self.edges: List[Tuple[str, str, RouteEntry]] = []
        seen_edges = set()
        for t in topo.tiles_on(noc):
            for r in t.routes:
                src = self._node_of.get(t.name)
                dst = self._node_of.get(r.next_tile)
                if src is None or dst is None or src == dst:
                    continue                       # intra-group / other noc
                ek = (src, dst, r.match, r.key)
                if ek in seen_edges:
                    continue
                seen_edges.add(ek)
                self.edges.append((src, dst, r))

    # kinds whose state/behavior is structurally singleton: lowering N
    # copies behind one dispatch stage would be meaningless or wrong
    _UNREPLICABLE = ("mgmt", "controller", "ctrl_in", "mgmt_ep",
                     "int_mirror", "watchdog")

    def _check_replica_group(self, gname: str, g: Dict) -> None:
        members = g.get("members") or []
        if not members:
            raise CompileError(
                f"replica group {gname!r} has no members — nothing to "
                f"lower behind the dispatch stage")
        kind = g.get("kind", "")
        if kind in self._UNREPLICABLE or kind.startswith("app:"):
            raise CompileError(
                f"replica group {gname!r} replicates kind {kind!r}, which "
                f"cannot be lowered (management/structural tiles are "
                f"singletons; app:* tiles scale via AppDecl.n_replicas)")
        policy = g.get("policy")
        if policy not in ("flow_hash", "round_robin", "port_match"):
            raise CompileError(
                f"replica group {gname!r} has un-lowerable dispatch "
                f"policy {policy!r} (expected flow_hash, round_robin or "
                f"port_match)")
        if policy == "port_match" and g.get("base_port") is None:
            raise CompileError(
                f"replica group {gname!r} uses port_match dispatch but "
                f"declares no base_port (replicate(..., base_port=...))")
        for m in members:
            if not self.topo.has_tile(m):
                raise CompileError(
                    f"replica group {gname!r} member {m!r} is not a "
                    f"declared tile")
            mk = self.topo.tile(m).kind
            if mk != kind:
                raise CompileError(
                    f"replica group {gname!r} mixes kinds {kind!r} and "
                    f"{mk!r} (member {m!r})")

    # ---- ordering --------------------------------------------------------
    def _reachable(self, ingress: str) -> List[str]:
        seen = {ingress}
        frontier = [ingress]
        while frontier:
            cur = frontier.pop()
            for s, d, _ in self.edges:
                if s == cur and d not in seen:
                    seen.add(d)
                    frontier.append(d)
        return sorted(seen, key=lambda n: self.nodes[n].index)

    def _topo_order(self, names: Sequence[str]) -> List[str]:
        names = set(names)
        indeg = {n: 0 for n in names}
        for s, d, _ in self.edges:
            if s in names and d in names:
                indeg[d] += 1
        order: List[str] = []
        ready = sorted([n for n, d in indeg.items() if d == 0],
                       key=lambda n: self.nodes[n].index)
        while ready:
            cur = ready.pop(0)
            order.append(cur)
            for s, d, _ in self.edges:
                if s == cur and d in indeg:
                    indeg[d] -= 1
                    if indeg[d] == 0:
                        ready.append(d)
            ready.sort(key=lambda n: self.nodes[n].index)
        if len(order) != len(names):
            cyc = sorted(names - set(order))
            raise CompileError(f"route graph has a cycle through {cyc}")
        return order

    def _latency_estimates(self, ingress: str,
                           names: Sequence[str]) -> Dict[str, int]:
        """Compile-time NoC latency (cycles) from the ingress tile to each
        node, along the shortest route-graph path (BFS)."""
        parent: Dict[str, Optional[str]] = {ingress: None}
        frontier = [ingress]
        while frontier:
            nxt = []
            for cur in frontier:
                for s, d, _ in self.edges:
                    if s == cur and d not in parent:
                        parent[d] = cur
                        nxt.append(d)
            frontier = nxt
        out = {}
        for n in names:
            path, cur = [], n
            while cur is not None:
                path.append(cur)
                cur = parent.get(cur)
            coords = [self.nodes[p].members[0].coord for p in reversed(path)]
            out[n] = chain_latency_cycles(coords, REF_PAYLOAD_BYTES)
        return out

    # ---- dead-stage pruning ----------------------------------------------
    # Route keys on ethertype / ip_proto are *structural*: a packet can
    # only carry one value per header field, so an edge keyed on a value
    # that contradicts what every upstream path already committed to can
    # never fire, and a node whose in-edges are all dead is untraceable
    # garbage — prune it before tracing instead of compiling a stage whose
    # predicate is constant-false.  Port-keyed routes (udp_port/tcp_port)
    # are never pruned: those CAMs are the runtime-rewritable surface
    # (ROUTE_SET), so their reachability is a runtime question.
    _STATIC_MATCH = ("ethertype", "ip_proto")

    def _prune_dead(self, start: str,
                    order: Sequence[str]) -> Tuple[List[str], List[str]]:
        """Constraint propagation over the route DAG: for each node, the
        set of values each static field can still hold on arriving
        packets (missing field = unconstrained).  Joins union field-wise
        (a conservative over-approximation — pruning only when *every*
        path contradicts).

        Soundness under repeated headers: predicates evaluate the *live*
        carrier meta, and a duplicated parse tile (e.g. the inner ip_rx
        behind an IP-in-IP decap, paper §3.5) rewrites its field for the
        whole batch.  A field rewritten by more than one compiled node is
        therefore runtime-dependent and exempt from pruning entirely —
        tile kinds declare what they rewrite via ``register_tile(...,
        rewrites=...)``."""
        def join(a, b):
            return {f: a[f] | b[f] for f in set(a) & set(b)}

        writers: Dict[str, int] = {}
        for n in order:
            for f in resolve_kind(self.nodes[n].kind).rewrites:
                writers[f] = writers.get(f, 0) + 1
        static = tuple(f for f in self._STATIC_MATCH
                       if writers.get(f, 0) <= 1)

        names = set(order)
        feasible: Dict[str, Dict[str, set]] = {start: {}}
        for n in order:
            if n == start:
                continue
            merged = None
            for s, d, r in self.edges:
                if d != n or s not in names or s not in feasible:
                    continue
                cs = feasible[s]
                if r.match in static and r.key is not None:
                    vals = cs.get(r.match)
                    if vals is not None and r.key not in vals:
                        continue               # edge contradicts upstream
                    cs = dict(cs)
                    cs[r.match] = {r.key}
                merged = cs if merged is None else join(merged, cs)
            if merged is not None:
                feasible[n] = merged
        return ([n for n in order if n in feasible],
                [n for n in order if n not in feasible])

    def _is_trunk(self, ingress: str, names, node: str) -> bool:
        """True when every packet path from the ingress passes through
        `node` (route-DAG post-dominance): no sink stays reachable once the
        node is removed.  A trunk alive-tile *gates* the whole stack (its
        pred & ok replaces the alive mask, like the hand-written chains);
        a branch alive-tile only judges the packets routed through it."""
        names = set(names)
        sinks = {n for n in names
                 if not any(s == n and d in names for s, d, _ in self.edges)}
        seen = {ingress} if ingress != node else set()
        frontier = list(seen)
        while frontier:
            cur = frontier.pop()
            for s, d, _ in self.edges:
                if s == cur and d in names and d != node and d not in seen:
                    seen.add(d)
                    frontier.append(d)
        return not (seen & sinks)

    # ---- compilation -----------------------------------------------------
    def compile(self, ingress: str) -> "CompiledPipeline":
        """Pipeline over every node reachable from `ingress` (a tile name)."""
        if ingress not in self._node_of:
            raise CompileError(f"unknown ingress tile {ingress!r}")
        start = self._node_of[ingress]
        names = self._reachable(start)
        order = self._topo_order(names)
        order, pruned = self._prune_dead(start, order)
        names = list(order)
        lats = self._latency_estimates(start, names)
        index_of = {n: i for i, n in enumerate(order)}

        # runtime route tables (the paper's runtime-rewritable CAMs): every
        # keyed route entry becomes a slot in a per-(source, match-space)
        # table held in state, so the control plane can rewrite dispatch
        # without recompiling.  Values are execution-node indices.
        table_entries: Dict[str, List[Tuple[int, int]]] = {}
        for s, d, r in self.edges:
            if (s in index_of and d in index_of and r.key is not None
                    and r.match in _MATCH_FIELD):
                table_entries.setdefault(f"{s}:{r.match}", []).append(
                    (r.key, index_of[d]))

        pipe_meta = {
            "order": order,
            # dispatch groups the management HEALTH_SET path addresses:
            # app groups AND lowered replica groups, in execution order
            "groups": [n for n in order
                       if self.nodes[n].kind.startswith("app:")
                       or n in self._rgroups],
            "tables": sorted(table_entries),
        }

        stages = []
        for i, n in enumerate(order):
            node = self.nodes[n]
            spec = resolve_kind(node.kind)
            if n in self._rgroups:
                # RSS lowering: the inner tile fn runs once over the whole
                # batch (replicas = batched lanes); the dispatch policy
                # table rides in the scan carry as runtime state
                g = self._rgroups[n]
                spec = dataclasses.replace(
                    spec,
                    fn=_replica_group_fn(spec.fn, n, g["policy"],
                                         g.get("base_port")),
                    init=_replica_group_init(spec.init, n,
                                             len(g["members"])))
            binding = self.bindings.get(n, self.bindings.get(node.kind))
            ctx = TileContext(name=n, kind=node.kind, members=node.members,
                              binding=binding, options=self.options,
                              lat_cycles=lats[n], index=i, pipe=pipe_meta)
            in_edges = [(s, r) for s, d, r in self.edges
                        if d == n and s in index_of]
            trunk = spec.alive and self._is_trunk(start, names, n)
            stages.append((node, spec, ctx, in_edges, trunk))
        return CompiledPipeline(start, stages, table_entries, pipe_meta,
                                pruned=pruned)


class CompiledPipeline:
    """One jittable executor: run(state, carrier) -> (state, carrier) per
    batch, or run_stream(state, payloads, lengths) for N device-resident
    batches under one lax.scan."""

    # carrier keys worth stacking out of a streamed run (whichever exist)
    STREAM_OUT_KEYS = ("tx_payload", "tx_len", "alive", "info", "tcp_resps",
                       "pc_payload", "pc_len", "pc_valid",
                       "alert_payload", "alert_len", "alert_valid")

    def __init__(self, ingress: str, stages, table_entries=None,
                 pipe_meta=None, pruned=None):
        self.ingress = ingress
        self.stages = stages
        self.table_entries = table_entries or {}
        self.pruned = list(pruned or [])
        self.pipe_meta = pipe_meta or {"order": self.order, "groups": [],
                                       "tables": []}
        self._index = {node.name: i
                       for i, (node, *_) in enumerate(self.stages)}
        # static per-node columns of the fused telemetry row block
        self._lat_cycles = jnp.asarray(
            [ctx.lat_cycles for _, _, ctx, *_ in self.stages], jnp.int32)
        self._node_idx = jnp.arange(len(self.stages), dtype=jnp.int32)
        # push-mode observability taps (repro.obs.{postcard,slo}): the
        # tiles are structural, the executor packs their egress frames
        local_ip = 0
        if self.stages:
            local_ip = int(self.stages[0][2].options.get("local_ip") or 0)
        self._mirror_cfg = None
        self._watchdog_cfg = None
        for node, _, ctx, *_ in self.stages:
            if node.kind == "int_mirror":
                self._mirror_cfg = postcard.tile_cfg(
                    node.members[0].params, local_ip)
            elif node.kind == "watchdog":
                self._watchdog_cfg = postcard.tile_cfg(
                    node.members[0].params, local_ip)

    @property
    def order(self) -> List[str]:
        return [node.name for node, *_ in self.stages]

    def summary(self) -> str:
        lines = []
        for node, _, ctx, in_edges, _trunk in self.stages:
            srcs = ", ".join(f"{s}[{r.match}"
                             f"{'' if r.key is None else '=' + hex(r.key)}]"
                             for s, r in in_edges) or "(ingress)"
            lines.append(f"{ctx.index:2d} {node.name:<12} kind={node.kind:<12}"
                         f" lat~{ctx.lat_cycles}cyc <- {srcs}")
        return "\n".join(lines)

    # ---- state -----------------------------------------------------------
    def init_state(self, with_telemetry: bool = True,
                   log_entries: int = telemetry.PIPE_LOG_ENTRIES,
                   with_obs: bool = True) -> Dict[str, Any]:
        st: Dict[str, Any] = {}
        for node, spec, ctx, *_ in self.stages:
            if spec.init is not None:
                deep_merge(st, spec.init(ctx))
        if self.table_entries:
            deep_merge(st, {"routes": {
                t: routing.make_table(ents)
                for t, ents in self.table_entries.items()}})
        if with_telemetry:
            deep_merge(st, {"telemetry": {
                "step": jnp.zeros((), jnp.int32),
                "nodes": telemetry.make_node_log(len(self.stages),
                                                 log_entries),
                "logs": {},
                "drops": telemetry.make_drop_table(len(self.stages),
                                                   reasons.NUM_REASONS),
            }})
            if with_obs:
                st["telemetry"]["obs"] = flight.make_obs(len(self.stages))
                st["telemetry"]["series"] = series.make_series(
                    len(self.stages))
        # logs served together over LOG_READ are stacked: every log must
        # share one ring depth (tile inits contribute extra logs, e.g.
        # tcp_cc.*, at telemetry.PIPE_LOG_ENTRIES) — reject a mismatch
        # here instead of crashing inside the compiled mgmt tile
        logs = st.get("telemetry", {}).get("logs", {})
        depths = {lg.entries.shape[0] for lg in logs.values()}
        if "nodes" in st.get("telemetry", {}):
            depths.add(st["telemetry"]["nodes"].entries.shape[0])
        if len(depths) > 1:
            raise ValueError(
                f"telemetry logs mix ring depths {sorted(depths)}; use "
                f"log_entries={telemetry.PIPE_LOG_ENTRIES} "
                f"(telemetry.PIPE_LOG_ENTRIES) when tile-contributed logs "
                f"are present")
        return st

    # ---- telemetry access ------------------------------------------------
    def node_log(self, state, name: str) -> telemetry.RingLog:
        """One node's counter rows out of the stacked node log, as an
        ordinary RingLog view (for `telemetry.latest` / `entry_at`)."""
        return telemetry.node_view(state["telemetry"]["nodes"],
                                   self._index[name])

    def node_logs(self, state) -> Dict[str, telemetry.RingLog]:
        return {n: self.node_log(state, n) for n in self.order}

    # ---- execution -------------------------------------------------------
    def run(self, state: Dict[str, Any], carrier: Dict[str, Any],
            with_telemetry: bool = True):
        """One batch through the chain.  ``telemetry["nodes"]`` (the
        stacked per-node counter log) is owned by the pipeline whose
        ``init_state`` created it — a pipeline running against another
        pipeline's state (e.g. the TCP TX build chain, whose returned
        state is discarded) must pass ``with_telemetry=False``."""
        state = dict(state)
        carrier = dict(carrier)
        carrier.setdefault("meta", {})
        carrier.setdefault("info", {})
        n = carrier["payload"].shape[0]

        telem = state.get("telemetry") if with_telemetry else None
        if telem is not None:
            src = state["telemetry"]
            telem = {"step": src["step"] + 1, "logs": dict(src["logs"])}
            for k in ("nodes", "drops", "series"):
                if k in src:
                    telem[k] = src[k]
            if "obs" in src:
                telem["obs"] = dict(src["obs"])
            state["telemetry"] = telem
        count_nodes = telem is not None and "nodes" in telem
        count_drops = telem is not None and "drops" in telem
        obs = telem.get("obs") if telem is not None else None

        routes_rt = state.get("routes")
        pkts_in: List[jnp.ndarray] = []
        drops: List[jnp.ndarray] = []
        bytes_l: List[jnp.ndarray] = []
        drop_blocks: List[jnp.ndarray] = []
        enters: List[jnp.ndarray] = []
        exits: List[jnp.ndarray] = []
        visits: List[jnp.ndarray] = []
        first_reason = jnp.zeros((n,), jnp.int32)
        zero_reason = jnp.zeros((n,), jnp.int32)
        ok_of: Dict[str, jnp.ndarray] = {}
        for node, spec, ctx, in_edges, trunk in self.stages:
            if not in_edges:                       # ingress / chain root
                pred = jnp.ones((n,), bool)
            else:
                pred = jnp.zeros((n,), bool)
                for src, route in in_edges:
                    tname = f"{src}:{route.match}"
                    if (route.key is not None and route.match in _MATCH_FIELD
                            and routes_rt is not None
                            and tname in routes_rt):
                        # live CAM lookup: the control plane can rewrite
                        # this table between batches (paper §4.2)
                        field = carrier["meta"][_MATCH_FIELD[route.match]]
                        nxt = routes_rt[tname].lookup(
                            field.astype(jnp.int32))
                        hit = nxt == self._index[node.name]
                    else:
                        hit = _match_pred(route, carrier, n)
                    pred = pred | (ok_of[src] & hit)
            carrier = dict(carrier)
            carrier["drop_reason"] = zero_reason   # tiles overwrite per row
            stage_len = carrier["length"]          # view before the tile
            state, carrier, ok = spec.fn(state, carrier, pred, ctx)
            ok_of[node.name] = pred & ok if ok is not None else pred
            if spec.alive:
                if trunk:      # gates all traffic: alive = arrived & ok
                    carrier["alive"] = ok_of[node.name]
                else:          # branch tile: judge only its own packets
                    prev = carrier.get("alive", jnp.ones((n,), bool))
                    carrier["alive"] = jnp.where(pred, ok_of[node.name],
                                                 prev)
            if count_nodes:
                pkts_in.append(pred.sum(dtype=jnp.int32))
                drops.append((pred & ~ok_of[node.name]).sum(dtype=jnp.int32))
                bytes_l.append(jnp.where(pred, stage_len,
                                         0).sum().astype(jnp.int32))
            if count_drops or obs is not None:
                # drop attribution: hard drops (arrived & failed) plus
                # soft drops (tile set a reason but kept the packet alive,
                # e.g. an app error reply); hard drops with no tile-
                # supplied code fall back to UNSPEC
                reason = carrier["drop_reason"]
                hard = pred & ~ok_of[node.name]
                counted = hard | (pred & (reason > 0))
                reason = jnp.where(counted & (reason == 0),
                                   reasons.UNSPEC, reason)
                if count_drops:
                    drop_blocks.append(telemetry.reason_counts(
                        reason, counted, reasons.NUM_REASONS))
                if obs is not None:
                    first_reason = jnp.where(
                        (first_reason == 0) & counted, reason, first_reason)
                    # per-frame stage occupancy proxy: static NoC latency
                    # estimate + arrival-queue position within the batch
                    q = jnp.cumsum(pred.astype(jnp.int32)) - 1
                    enters.append(ctx.lat_cycles + q)
                    exits.append(ctx.lat_cycles + q + 1)
                    visits.append(pred)

        # ---- fused telemetry: ONE stacked row write for the whole batch --
        # (the per-stage masked appends collapsed into a single
        # (num_nodes, LOG_WIDTH) scatter; readback therefore serves rows
        # *through the previous batch* — the batch's own row lands when it
        # completes, like a telemetry DMA at pipeline egress)
        if count_nodes:
            rows = telemetry.counter_rows(
                telem["step"], jnp.stack(pkts_in), jnp.stack(drops),
                self._lat_cycles, self._node_idx)
            telem["nodes"] = telemetry.append_stacked(telem["nodes"], rows)
        if count_drops and drop_blocks:
            # ONE fused (num_nodes, NUM_REASONS) add per batch — same
            # egress-DMA discipline as the counter rows above, so DROP_READ
            # serves totals *through the previous batch*
            telem["drops"] = telem["drops"] + jnp.stack(drop_blocks)

        # ---- flight recorder + latency histograms (device-resident) ------
        if obs is not None and visits:
            nstages = len(self.stages)
            E = jnp.stack(enters, axis=1)              # (B, nstages)
            X = jnp.stack(exits, axis=1)
            V = jnp.stack(visits, axis=1)              # (B, nstages) bool
            en = (obs["ctrl"]["enable"] != 0)
            en_i = en.astype(jnp.int32)
            # per-stage occupancy (queue depth seen) + end-to-end rows
            occ = X - self._lat_cycles[None, :]
            hrows = [flight.bucket_counts(occ[:, i], V[:, i])
                     for i in range(nstages)]
            e2e = jnp.where(V, X, 0).max(axis=1) - E[:, 0]
            hrows.append(flight.bucket_counts(e2e, V[:, 0]))
            obs["histo"] = obs["histo"] + jnp.stack(hrows) * en_i
            # sampled per-frame trace rows, ONE fused ring append per batch
            fid = obs["frame_ctr"] + jnp.arange(n, dtype=jnp.int32)
            sampled = flight.sample_mask(obs["ctrl"], fid)
            bitmap = jnp.sum(
                jnp.left_shift(V.astype(jnp.int32),
                               jnp.arange(nstages, dtype=jnp.int32)[None, :]),
                axis=1)
            stepcol = jnp.broadcast_to(telem["step"], (n,))
            trow = jnp.concatenate(
                [fid[:, None], stepcol[:, None], bitmap[:, None],
                 first_reason[:, None],
                 jnp.stack([E, X], axis=2).reshape(n, 2 * nstages)], axis=1)
            obs["trace"] = telemetry.append(obs["trace"], trow, sampled)
            obs["frame_ctr"] = obs["frame_ctr"] + n
            telem["obs"] = obs

            # ---- push-mode observability (paper-adjacent INT postcards,
            # series ring, SLO watchdog — repro.obs.{series,postcard,slo})
            if "series" in telem and count_nodes:
                # per-stage TCP retransmission totals (tcp_rx row only):
                # stored cumulatively, so the window delta falls out of
                # the series' cum-prev subtraction like the other metrics
                retx_col = jnp.zeros((nstages,), jnp.int32)
                ccs = state.get("conn")
                ccs = ccs.get("cc") if isinstance(ccs, dict) else None
                if ccs is not None and "tcp_rx" in self._index:
                    total = (ccs["retx_fast"]
                             + ccs["retx_timer"]).sum().astype(jnp.int32)
                    retx_col = retx_col.at[self._index["tcp_rx"]].set(total)
                telem["series"] = series.update(
                    telem["series"], jnp.stack(pkts_in), jnp.stack(drops),
                    jnp.stack(bytes_l), retx_col, obs["histo"])
            if self._mirror_cfg is not None:
                # one fused pack per batch; validity = the recorder's
                # sample mask, so the mirror obeys the same runtime
                # obs_ctrl knobs (TRACE_SET) with no retrace.  lax.cond
                # skips the pack at runtime for batches with no sampled
                # frame (the common case at production 1/64 sampling).
                fb = postcard.frame_bytes(nstages)

                def _pc_pack(_):
                    pc, pl = postcard.pack(
                        self._mirror_cfg, carrier.get("meta"),
                        telem["step"], fid, E, X, V,
                        flight.bucket_of(occ), first_reason)
                    return pc, pl.astype(jnp.int32)

                def _pc_skip(_):
                    return (jnp.zeros((n, fb), jnp.uint8),
                            jnp.zeros((n,), jnp.int32))

                pc, pclen = jax.lax.cond(sampled.any(), _pc_pack,
                                         _pc_skip, None)
                carrier["pc_payload"] = pc
                carrier["pc_len"] = pclen
                carrier["pc_valid"] = sampled
            if self._watchdog_cfg is not None and "slo" in state \
                    and "series" in telem:
                # rules only re-evaluate on the batch that closed a
                # window (wr advanced past the watchdog's last look);
                # edges are rarer still, so the alert pack nests one
                # level deeper
                nr = state["slo"]["active"].shape[0]
                ab = slo.ALERT_BODY_BYTES + postcard.STACK_BYTES
                fresh = telem["series"]["wr"] > state["slo"]["last_wr"]

                def _wd_eval(_):
                    sl, edge, val = slo.evaluate(state["slo"],
                                                 telem["series"])

                    def _al_pack(_):
                        ap, al = slo.alert_frames(
                            self._watchdog_cfg, sl, telem["series"],
                            edge, val)
                        return ap, al.astype(jnp.int32)

                    def _al_skip(_):
                        return (jnp.zeros((nr, ab), jnp.uint8),
                                jnp.zeros((nr,), jnp.int32))

                    ap, al = jax.lax.cond(edge.any(), _al_pack,
                                          _al_skip, None)
                    return sl, edge, ap, al

                def _wd_idle(_):
                    return (state["slo"],
                            jnp.zeros((nr,), jnp.bool_),
                            jnp.zeros((nr, ab), jnp.uint8),
                            jnp.zeros((nr,), jnp.int32))

                sl, edge, ap, al = jax.lax.cond(fresh, _wd_eval,
                                                _wd_idle, None)
                carrier["alert_payload"] = ap
                carrier["alert_len"] = al
                carrier["alert_valid"] = edge
                state["slo"] = sl

        # ---- post-batch table commit (management plane) ------------------
        # A management tile stages table writes in the carrier; they are
        # committed here, after every stage has run, so a command always
        # takes effect on the *next* batch — live reconfiguration with no
        # recompile and no intra-batch ordering hazards (paper §3.6).
        staged = carrier.get("mgmt_staged")
        if staged is not None:
            if staged.get("nat") is not None and "nat" in state:
                state["nat"] = staged["nat"]
            if staged.get("healthy") and "dispatch" in state:
                disp = dict(state["dispatch"])
                for gname, h in staged["healthy"].items():
                    # only the control-owned field: the batch's rr_counter
                    # advances stay intact
                    disp[gname] = dataclasses.replace(disp[gname], healthy=h)
                state["dispatch"] = disp
            if staged.get("routes") is not None:
                state["routes"] = staged["routes"]
            if staged.get("rate") is not None and "rate" in state:
                state["rate"] = staged["rate"]
            if staged.get("cc") is not None and "conn" in state \
                    and "cc" in state["conn"]:
                conn = dict(state["conn"])
                conn["cc"] = staged["cc"]
                state["conn"] = conn
            if staged.get("obs_ctrl") is not None and telem is not None \
                    and "obs" in telem:
                # recorder knobs are runtime state: TRACE_SET takes effect
                # next batch, sampling modulus changes with no retrace
                o = dict(telem["obs"])
                o["ctrl"] = staged["obs_ctrl"]
                telem["obs"] = o
            if staged.get("slo") is not None and "slo" in state:
                # commit rule fields only — the watchdog's own
                # active/last_wr/alerts updates from this batch's
                # evaluation must survive the commit.  A rewritten slot
                # is unlatched (clear_active) so hysteresis restarts
                # from the new thresholds.
                su = staged["slo"]
                s = dict(state["slo"])
                for k in ("metric", "node", "thr_raise", "thr_clear",
                          "enabled"):
                    s[k] = su[k]
                s["active"] = jnp.where(su["clear_active"] != 0,
                                        jnp.zeros_like(s["active"]),
                                        s["active"])
                state["slo"] = s
            if staged.get("series_win") is not None and telem is not None \
                    and "series" in telem:
                ser = dict(telem["series"])
                ser["win_len"] = staged["series_win"]
                telem["series"] = ser
        return state, carrier

    # ---- streaming execution (device-resident multi-batch) ---------------
    def run_stream(self, state: Dict[str, Any], payloads, lengths,
                   out_keys: Optional[Sequence[str]] = None):
        """Run N batches device-resident under ONE ``lax.scan``: state is
        the scan carry, ``payloads`` is a (N, B, L) frame arena with
        (N, B) ``lengths``, and the selected carrier outputs come back
        stacked along the leading axis.  One dispatch, zero host syncs in
        the scanned region, bit-identical to N sequential :meth:`run`
        calls (telemetry counters and post-batch management commits
        included — a table staged by batch i is live for batch i+1
        *inside* the stream).

        Returns ``(state', outs)`` with ``outs[k]`` of shape (N, ...).
        ``out_keys`` selects which carrier keys to stack (default:
        whichever of :data:`STREAM_OUT_KEYS` the chain produces)."""
        keys = self.STREAM_OUT_KEYS if out_keys is None else tuple(out_keys)

        def step(st, xs):
            p, l = xs
            st, carrier = self.run(st, {"payload": p, "length": l})
            return st, {k: carrier[k] for k in keys if k in carrier}

        return jax.lax.scan(step, state, (payloads, lengths))


# ---------------------------------------------------------------------------
# replica-group lowering: RSS dispatch in front of a cloned hot tile
# (core.scaleout.replicate on udp_rx / rs_serve / lm_serve / tcp_rx ...).
# The inner tile fn runs ONCE over the whole batch — replicas are batched
# *lanes*, and the dispatch stage assigns each row its lane from the live
# policy table (flow_hash / round_robin / port_match).  The table is scan-
# carry state, so HEALTH_SET / drain_replica re-balances the lanes on the
# next batch with no retrace, exactly like the app-group dispatch path.


def _replica_group_init(inner: Optional[Callable], gname: str, n: int):
    def init(ctx: TileContext) -> dict:
        from repro.core.scaleout import make_dispatch
        st = inner(ctx) if inner is not None else {}
        deep_merge(st, {"dispatch": {gname: make_dispatch(list(range(n)))}})
        return st
    return init


def _replica_group_fn(inner: Callable, gname: str, policy: str,
                      base_port: Optional[int]):
    def fn(state, carrier, pred, ctx):
        from repro.core.scaleout import dispatch_lane
        # the inner kind may parse the very fields the hash keys on
        # (udp_rx writes src_port/dst_port), so the lane assignment reads
        # the *post-parse* meta — the NIC-RSS view of the full header
        state, carrier, ok = inner(state, carrier, pred, ctx)
        dispatch = dict(state["dispatch"])
        d, lane = dispatch_lane(dispatch[gname], policy, carrier["meta"],
                                pred, base_port)
        dispatch[gname] = d
        state = dict(state)
        state["dispatch"] = dispatch
        carrier = dict(carrier)
        info = dict(carrier["info"])
        info[f"{gname}.lane"] = jnp.where(pred, lane, -1)
        carrier["info"] = info
        return state, carrier, ok
    return fn


# ---------------------------------------------------------------------------
# the generic app-group tile function (dispatch + process, paper §4.2/§5)


def _app_init(ctx: TileContext) -> dict:
    from repro.core.scaleout import make_dispatch
    a = ctx.binding
    if a is None:
        raise CompileError(f"app group {ctx.name!r} has no binding")
    # fresh buffers per init_state: the AppDecl holds its template state
    # by reference, and aliased arrays across two init_state() calls would
    # let a donated run (run_stream's stream_fn) delete another state's
    # buffers out from under it
    fresh = jax.tree_util.tree_map(lambda x: jnp.array(x), a.state)
    return {"dispatch": {a.name: make_dispatch(list(range(a.n_replicas)))},
            "apps": {a.name: fresh}}


@register_tile("app", init=_app_init)
def _app_group(state, carrier, pred, ctx):
    """Replica dispatch + app processing for one app group.

    `pred` IS the arrival predicate derived from the udp_port route
    entries, so port matching lives in the topology, not here."""
    from repro.core.scaleout import dispatch_lane
    a = ctx.binding
    m = carrier["meta"]
    at_app = pred

    dispatch = dict(state["dispatch"])
    apps = dict(state["apps"])
    d, replica = dispatch_lane(dispatch[a.name], a.policy, m, at_app,
                               base_port=a.port)
    dispatch[a.name] = d

    ast, nb, nl = a.process(apps[a.name], carrier["body"], carrier["blen"],
                            m, at_app, replica)
    apps[a.name] = ast
    state = dict(state)
    state["dispatch"] = dispatch
    state["apps"] = apps

    carrier["out_body"] = jnp.where(at_app[:, None], nb, carrier["out_body"])
    carrier["out_blen"] = jnp.where(at_app, nl, carrier["out_blen"])
    info = dict(carrier["info"])
    info[a.name] = at_app
    carrier["info"] = info
    return state, carrier, None
