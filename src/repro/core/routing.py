"""Node-table routing (paper §3.4, §4.2).

Each tile owns a small match table — the FPGA CAM — mapping a header field
(ethertype, ip_proto, udp/tcp port, flow hash, virtual IP) to the next tile
id.  Tables are *runtime arrays* held in tile state: the control plane can
rewrite them without touching the compiled program, exactly like the
paper's runtime-rewritable hash tables.  Packets with no matching entry are
dropped (unsupported-traffic filtering, paper §4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

DROP = -1          # next-hop id meaning "drop the packet"
TABLE_SLOTS = 16   # CAM entries per tile


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RouteTable:
    """Fixed-capacity match table: (key -> next tile id)."""
    keys: jnp.ndarray      # (TABLE_SLOTS,) int32; -1 = empty slot
    values: jnp.ndarray    # (TABLE_SLOTS,) int32; tile id
    default: jnp.ndarray   # () int32; next hop for wildcard (DROP = drop)

    def lookup(self, field):
        """field: (B,) int32 -> next tile id (B,) int32 (DROP if no match)."""
        hit = self.keys[None, :] == field[:, None]          # (B, S)
        any_hit = hit.any(axis=1)
        idx = jnp.argmax(hit, axis=1)
        val = self.values[idx]
        return jnp.where(any_hit, val, self.default)

    def set_entry(self, slot, key, value) -> "RouteTable":
        """Runtime rewrite (control plane): returns a new table."""
        return RouteTable(
            keys=self.keys.at[slot].set(jnp.int32(key)),
            values=self.values.at[slot].set(jnp.int32(value)),
            default=self.default,
        )


def make_table(entries: Sequence[Tuple[Optional[int], int]],
               default: int = DROP) -> RouteTable:
    keys = [-1] * TABLE_SLOTS
    vals = [DROP] * TABLE_SLOTS
    i = 0
    for key, value in entries:
        if key is None:
            default = value
            continue
        keys[i], vals[i] = int(key), int(value)
        i += 1
    return RouteTable(jnp.asarray(keys, jnp.int32),
                      jnp.asarray(vals, jnp.int32),
                      jnp.asarray(default, jnp.int32))


def tables_from_topology(topo, tile_ids: Dict[str, int]) -> Dict[str, RouteTable]:
    """Build the initial routing tables from the declarative config — the
    paper's 'initial packet-level routing set up at compile time'."""
    out = {}
    for t in topo.tiles:
        entries = []
        default = DROP
        for r in t.routes:
            nid = tile_ids[r.next_tile]
            if r.key is None or r.match in ("const", "rr"):
                default = nid
            else:
                entries.append((r.key, nid))
        out[t.name] = make_table(entries, default)
    return out


# ---------------------------------------------------------------------------
# flow hashing (4-tuple) for stateful load balancing — FNV-1a over the tuple


def fnv1a(fields: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """fields: list of (B,) int32/uint32 -> (B,) uint32 hash."""
    h = jnp.uint32(0x811C9DC5)
    prime = jnp.uint32(0x01000193)
    for f in fields:
        x = f.astype(jnp.uint32)
        for shift in (0, 8, 16, 24):
            byte = (x >> shift) & jnp.uint32(0xFF)
            h = (h ^ byte) * prime
    return h


def flow_hash(meta: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """Standard 4-tuple hash: (src_ip, dst_ip, src_port, dst_port).

    FNV-1a's multiply only diffuses bits *upward*, so bit k of the raw
    hash is a linear function of input bits <= k — taking it mod a small
    replica count collapses (e.g. a client whose src_ip and src_port
    step together hits one RSS lane forever).  A murmur3-style avalanche
    finalizer makes every output bit depend on every input bit."""
    h = fnv1a([meta["src_ip"], meta["dst_ip"],
               meta["src_port"], meta["dst_port"]])
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h
