"""Compile-time message-deadlock analysis (paper §3.5).

Model: wormhole switching with dimension-ordered routing.  Routing-level
deadlock is impossible under DOR (Dally & Seitz); *message-level* deadlock
remains because a tile chain (Eth -> IP -> UDP -> App) holds NoC channels
while acquiring more.  We build the channel-dependency graph: for every
declared chain, the ordered list of channels it traverses contributes edges
c_i -> c_{i+1}; additionally every chain must never re-acquire a channel it
already holds (self-deadlock, paper Fig. 5a).  Any cycle in the union graph
is a potential deadlock; the designer must re-place tiles (Fig. 5b) or
duplicate them (IP-in-IP) until the graph is acyclic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import networkx as nx

from repro.core.noc import Channel, chain_channels
from repro.core.topology import TopologyConfig


@dataclasses.dataclass
class DeadlockReport:
    ok: bool
    self_conflicts: List[Tuple[List[str], Channel]]
    cycles: List[List[Channel]]

    def summary(self) -> str:
        if self.ok:
            return "deadlock-free: channel dependency graph is acyclic"
        lines = []
        for chain, ch in self.self_conflicts:
            lines.append(f"chain {'->'.join(chain)} re-acquires channel {ch}")
        for cyc in self.cycles:
            lines.append("cycle: " + " -> ".join(map(repr, cyc)))
        return "\n".join(lines)


def analyze(topo: TopologyConfig, noc: str = "data") -> DeadlockReport:
    errors = topo.validate()
    if errors:
        raise ValueError("invalid topology:\n" + "\n".join(errors))

    g = nx.DiGraph()
    self_conflicts = []
    for chain, channels in topo.chain_channel_lists():
        seen = set()
        for ch in channels:
            if ch in seen:
                self_conflicts.append((chain, ch))
            seen.add(ch)
        for a, b in zip(channels, channels[1:]):
            g.add_edge(a, b)

    cycles = list(nx.simple_cycles(g))
    ok = not cycles and not self_conflicts
    return DeadlockReport(ok=ok, self_conflicts=self_conflicts,
                          cycles=[c for c in cycles])


def assert_deadlock_free(topo: TopologyConfig) -> None:
    rep = analyze(topo)
    if not rep.ok:
        raise RuntimeError(
            f"topology {topo.name!r} can deadlock:\n{rep.summary()}\n"
            "Re-place tiles so chains acquire channels in order, or "
            "duplicate tiles (paper §3.5).")
