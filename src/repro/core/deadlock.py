"""Compile-time message-deadlock analysis (paper §3.5).

Model: wormhole switching with dimension-ordered routing.  Routing-level
deadlock is impossible under DOR (Dally & Seitz); *message-level* deadlock
remains because a tile chain (Eth -> IP -> UDP -> App) holds NoC channels
while acquiring more.  We build the channel-dependency graph: for every
declared chain, the ordered list of channels it traverses contributes edges
c_i -> c_{i+1}; additionally every chain must never re-acquire a channel it
already holds (self-deadlock, paper Fig. 5a).  Any cycle in the union graph
is a potential deadlock; the designer must re-place tiles (Fig. 5b) or
duplicate them (IP-in-IP) until the graph is acyclic.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import networkx as nx

from repro.core.noc import Channel, chain_channels
from repro.core.topology import TopologyConfig


@dataclasses.dataclass
class DeadlockReport:
    ok: bool
    self_conflicts: List[Tuple[List[str], Channel]]
    cycles: List[List[Channel]]

    def summary(self) -> str:
        if self.ok:
            return "deadlock-free: channel dependency graph is acyclic"
        lines = []
        for chain, ch in self.self_conflicts:
            lines.append(f"chain {'->'.join(chain)} re-acquires channel {ch}")
        for cyc in self.cycles:
            lines.append("cycle: " + " -> ".join(map(repr, cyc)))
        return "\n".join(lines)


def analyze(topo: TopologyConfig, noc: str = "data") -> DeadlockReport:
    """Per-NoC analysis: each NoC has its own physical channels (paper
    §3.6 — the management NoC is a separate, narrower mesh), so only the
    chains whose tiles live on `noc` contribute to its dependency graph.
    Control chains can therefore never deadlock a dataplane chain, and
    vice versa."""
    errors = topo.validate()
    if errors:
        raise ValueError("invalid topology:\n" + "\n".join(errors))

    noc_of = {t.name: t.noc for t in topo.tiles}
    g = nx.DiGraph()
    self_conflicts = []
    for chain, channels in topo.chain_channel_lists():
        if any(noc_of.get(n, "data") != noc for n in chain):
            continue
        seen = set()
        for ch in channels:
            if ch in seen:
                self_conflicts.append((chain, ch))
            seen.add(ch)
        for a, b in zip(channels, channels[1:]):
            g.add_edge(a, b)

    cycles = list(nx.simple_cycles(g))
    ok = not cycles and not self_conflicts
    return DeadlockReport(ok=ok, self_conflicts=self_conflicts,
                          cycles=[c for c in cycles])


def assert_deadlock_free(topo: TopologyConfig) -> None:
    """Every NoC in the topology must be independently deadlock-free."""
    for noc in sorted({t.noc for t in topo.tiles}):
        rep = analyze(topo, noc=noc)
        if not rep.ok:
            raise RuntimeError(
                f"topology {topo.name!r} can deadlock on noc {noc!r}:\n"
                f"{rep.summary()}\n"
                "Re-place tiles so chains acquire channels in order, or "
                "duplicate tiles (paper §3.5).")
