"""NoC message / packet-batch representation.

The paper's NoC message = header flit (routing) + metadata flits (parsed
protocol headers) + data flits (payload).  On a batch machine the runtime
moves *batches* of messages: payload is a (B, MAX_LEN) uint8 tensor, the
metadata flits become a dict of (B,) int32 fields that protocol tiles
append as they parse, and the header flit becomes the per-packet location
(current tile id) + validity mask.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketBatch:
    payload: jnp.ndarray            # (B, L) uint8
    length: jnp.ndarray             # (B,) int32 — valid bytes in payload
    valid: jnp.ndarray              # (B,) bool — packet alive (not dropped)
    loc: jnp.ndarray                # (B,) int32 — current tile id
    meta: Dict[str, jnp.ndarray]    # parsed header fields, each (B,) int32

    @property
    def batch(self) -> int:
        return self.payload.shape[0]

    def with_meta(self, **kv) -> "PacketBatch":
        meta = dict(self.meta)
        meta.update(kv)
        return dataclasses.replace(self, meta=meta)

    def drop(self, mask) -> "PacketBatch":
        return dataclasses.replace(self, valid=self.valid & ~mask)

    def at(self, loc) -> "PacketBatch":
        return dataclasses.replace(
            self, loc=jnp.where(self.valid, loc, self.loc))


def make_batch(payload, length, tile_id: int = 0, meta=None) -> PacketBatch:
    payload = jnp.asarray(payload, jnp.uint8)
    B = payload.shape[0]
    return PacketBatch(
        payload=payload,
        length=jnp.asarray(length, jnp.int32),
        valid=jnp.ones((B,), bool),
        loc=jnp.full((B,), tile_id, jnp.int32),
        meta=dict(meta or {}),
    )


def empty_like(b: PacketBatch) -> PacketBatch:
    return dataclasses.replace(b, valid=jnp.zeros_like(b.valid))
