"""In-band telemetry postcards (INT "postcard mode", PAPERS.md: The
Programmable Data Plane; FlexiNS header-stamping offload).

For every frame selected by the *existing* flight-recorder sampling knobs
(``obs_ctrl`` — runtime state, no retrace), the executor emits one extra
egress frame: a UDP datagram to a collector carrying a flow digest plus
one fixed-size TLV per pipeline stage, harvested from the same
enter/exit/visit arrays the recorder already computes.  The whole batch
is packed in one fused sequence of static-offset stores at egress —
fixed shapes, zero host callbacks.

Wire format (RPC body, ``MSG_POSTCARD``):

    off  size  field
    0    1     version (=1)
    1    1     nhops (= num pipeline stages)
    2    1     first drop reason code (repro.obs.reasons)
    3    1     flags (bit0: frame was dropped in-pipeline)
    4    4     frame id (recorder frame counter)
    8    4     step (batch counter at egress)
    12   4     src ip        }
    16   4     dst ip        }  flow digest (RX orientation)
    20   2     src port      }
    22   2     dst port      }
    24   12*i  hop TLV i: [stage u8][visited u8][occ_bucket u8][rsv u8]
                          [enter_cycles u32][exit_cycles u32]

The postcard rides the normal egress path: RPC -> UDP -> IPv4 -> Eth,
addressed to the ``int_mirror`` tile's collector params.  Host-side
decode lives in :mod:`repro.obs.collector`.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import eth, ipv4, rpc, udp

VERSION = 1
HDR_BYTES = 24
HOP_BYTES = 12
STACK_BYTES = eth.ETH_HLEN + ipv4.IP_HLEN + udp.UDP_HLEN + rpc.HLEN  # 51

DEFAULT_COLLECTOR_PORT = 9966
DEFAULT_ALERT_PORT = 9967
DEFAULT_SRC_PORT = 9965
# locally-administered MACs for the mirror port and the collector
DEFAULT_SRC_MAC = (0x02BEE500, 0x0001)
DEFAULT_DST_MAC = (0x02BEE500, 0x00C0)


def body_bytes(num_nodes: int) -> int:
    return HDR_BYTES + HOP_BYTES * num_nodes


def frame_bytes(num_nodes: int) -> int:
    return body_bytes(num_nodes) + STACK_BYTES


def egress_frame(body, blen, msg_type, req_id, cfg):
    """Wrap an RPC body into a full Eth/IPv4/UDP frame to the collector.

    body: (B, W) uint8 with W >= blen + STACK_BYTES headroom.  cfg is the
    mirror/watchdog tile param dict (collector_ip/port, src_ip/port,
    MACs).  Returns (frames, lengths).
    """
    n = body.shape[0]
    u32 = lambda v: jnp.full((n,), v, jnp.uint32)
    out, ln = rpc.build(body, blen, msg_type, req_id)
    meta = {"src_port": u32(cfg["src_port"]),
            "dst_port": u32(cfg["collector_port"]),
            "src_ip": u32(cfg["src_ip"]),
            "dst_ip": u32(cfg["collector_ip"]),
            "ip_proto": u32(ipv4.PROTO_UDP)}
    out, ln = udp.build(out, ln, meta)
    out, ln = ipv4.build(out, ln, meta)
    emeta = {"eth_src_hi": u32(cfg["eth_src_hi"]),
             "eth_src_lo": u32(cfg["eth_src_lo"]),
             "eth_dst_hi": u32(cfg["eth_dst_hi"]),
             "eth_dst_lo": u32(cfg["eth_dst_lo"]),
             "ethertype": u32(eth.ETHERTYPE_IPV4)}
    out, ln = eth.build(out, ln, emeta)
    return out, ln


def tile_cfg(params, local_ip=0):
    """Normalise int_mirror/watchdog tile params into an egress config."""
    p = params or {}
    return {
        "collector_ip": int(p.get("collector_ip", 0)),
        "collector_port": int(p.get("collector_port", DEFAULT_COLLECTOR_PORT)),
        "src_ip": int(p.get("src_ip", local_ip)),
        "src_port": int(p.get("src_port", DEFAULT_SRC_PORT)),
        "eth_src_hi": int(p.get("eth_src_hi", DEFAULT_SRC_MAC[0])),
        "eth_src_lo": int(p.get("eth_src_lo", DEFAULT_SRC_MAC[1])),
        "eth_dst_hi": int(p.get("eth_dst_hi", DEFAULT_DST_MAC[0])),
        "eth_dst_lo": int(p.get("eth_dst_lo", DEFAULT_DST_MAC[1])),
    }


def _be16b(a):
    """(...,) -> (..., 2) big-endian uint8 bytes."""
    a = a.astype(jnp.uint32)
    return jnp.stack([a >> 8, a], axis=-1).astype(jnp.uint8)


def _be32b(a):
    """(...,) -> (..., 4) big-endian uint8 bytes."""
    a = a.astype(jnp.uint32)
    return jnp.stack([a >> 24, a >> 16, a >> 8, a], axis=-1).astype(jnp.uint8)


def pack(cfg, meta, step, fid, enters, exits, visits, occ_bucket,
         first_reason):
    """One fused pack: (B,) frame batch -> (B, frame_bytes) postcards.

    enters/exits/visits/occ_bucket: (B, num_nodes); first_reason: (B,).
    meta is the carrier meta dict at egress (RX-oriented flow fields may
    be absent on non-IP pipelines — they default to 0).  The whole body
    is assembled as one concatenation of byte planes — no per-field
    scatter, so the per-batch cost is a handful of fused ops.
    """
    n, num_nodes = enters.shape
    bb = body_bytes(num_nodes)
    z = jnp.zeros((n,), jnp.uint32)
    g = lambda k: meta.get(k, z).astype(jnp.uint32) if meta else z
    fr = first_reason.astype(jnp.uint32)

    hdr = jnp.concatenate([
        jnp.full((n, 1), VERSION, jnp.uint8),
        jnp.full((n, 1), num_nodes, jnp.uint8),
        fr[:, None].astype(jnp.uint8),
        (fr > 0)[:, None].astype(jnp.uint8),
        _be32b(fid), _be32b(jnp.broadcast_to(step, (n,))),
        _be32b(g("src_ip")), _be32b(g("dst_ip")),
        _be16b(g("src_port")), _be16b(g("dst_port")),
    ], axis=1)                                        # (n, HDR_BYTES)
    stage = jnp.broadcast_to(jnp.arange(num_nodes, dtype=jnp.uint8),
                             (n, num_nodes))
    tlv = jnp.concatenate([
        stage[..., None],
        visits[..., None].astype(jnp.uint8),
        occ_bucket[..., None].astype(jnp.uint8),
        jnp.zeros((n, num_nodes, 1), jnp.uint8),
        _be32b(enters), _be32b(exits),
    ], axis=-1).reshape(n, num_nodes * HOP_BYTES)
    body = jnp.concatenate(
        [hdr, tlv, jnp.zeros((n, STACK_BYTES), jnp.uint8)], axis=1)

    blen = jnp.full((n,), bb, jnp.int32)
    return egress_frame(body, blen, rpc.MSG_POSTCARD,
                        fid.astype(jnp.uint32), cfg)


def bind_mirror(topo, collector_ip, collector_port=DEFAULT_COLLECTOR_PORT,
                **params):
    """Add the `int_mirror` tile to a topology, fed from eth_tx.

    Widens the mesh by one column and declares the mirror's egress chain
    so the extra postcard traffic participates in deadlock analysis.
    """
    base_x = topo.dim_x
    topo.dim_x = base_x + 1
    p = dict(params)
    p["collector_ip"] = collector_ip
    p["collector_port"] = collector_port
    topo.add_tile("int_mirror", "int_mirror", base_x, 1, params=p)
    topo.add_route("eth_tx", "const", None, "int_mirror")
    topo.add_chain("eth_tx", "int_mirror")
    return "int_mirror"
