"""Static reason-coverage check: every tile that can squash packets must
attribute a drop reason.

The drop table (:mod:`repro.obs.reasons`) is only total if every tile
that can return a non-None ``ok`` mask — i.e. can veto packets — also
writes ``carrier["drop_reason"]``.  A future tile that forgets leaves
its drops attributed to ``unspec``, which silently degrades the push
pipeline (postcard ``first_reason``, series drop rates, watchdog rules
keyed on them).  This check walks the registered tile functions'
*source* (AST — no tracing) and fails with the offender list, so the
gap is caught by ``make lint-reasons`` / the test suite, not by an
operator staring at ``unspec`` counts.

A tile "can squash" when any top-level ``return`` statement's third
tuple element is not the literal ``None`` (nested defs, e.g. helper
closures, are ignored).  It "attributes" when the token
``drop_reason`` appears in its source.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import List


def _top_level_returns(fn) -> List[ast.Return]:
    src = textwrap.dedent(inspect.getsource(fn))
    fdef = ast.parse(src).body[0]
    if not isinstance(fdef, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return []
    outer: List[ast.Return] = []

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue                     # helper closures don't count
            if isinstance(child, ast.Return):
                outer.append(child)
            walk(child)

    walk(fdef)
    return outer


def _can_squash(fn) -> bool:
    """True if any top-level return's ok element is not literal None."""
    for ret in _top_level_returns(fn):
        v = ret.value
        if isinstance(v, ast.Tuple) and len(v.elts) == 3:
            ok = v.elts[2]
            if isinstance(ok, ast.Constant) and ok.value is None:
                continue
            return True
        elif v is not None:
            return True                      # non-tuple return: be strict
    return False


def check_reason_coverage() -> List[str]:
    """Offending tile kinds: can squash but never touch drop_reason.
    Imports the standard tile modules first so the registry is full."""
    import repro.mgmt.plane    # noqa: F401  (registers mgmt tiles)
    import repro.net.tiles     # noqa: F401  (registers protocol tiles)
    from repro.core.compiler import TILE_REGISTRY

    bad = []
    for kind in sorted(TILE_REGISTRY):
        fn = TILE_REGISTRY[kind].fn
        try:
            squashes = _can_squash(fn)
            src = textwrap.dedent(inspect.getsource(fn))
        except (OSError, TypeError, SyntaxError):
            continue                         # no source (builtin/dynamic)
        if squashes and "drop_reason" not in src:
            bad.append(kind)
    return bad


def check_topology_coverage(topo) -> List[str]:
    """Reason-coverage offenders among the tile kinds one topology
    actually instantiates, with replica groups resolved to their member
    kind: a clone's lowered stage wraps the *base kind's* registered
    function (`compiler._replica_group_fn`), so checking that kind is
    exactly checking every clone — replication can never lose drop
    attribution.  ``app:*`` kinds are bound at compile time and have no
    registry entry; they are skipped."""
    from repro.core.compiler import TILE_REGISTRY

    kinds = {t.kind for t in topo.tiles}
    for g in getattr(topo, "replica_groups", {}).values():
        kinds.add(g["kind"])
    bad = []
    for kind in sorted(kinds):
        spec = TILE_REGISTRY.get(kind)
        if spec is None:
            continue                         # app:* — compile-time bound
        try:
            squashes = _can_squash(spec.fn)
            src = textwrap.dedent(inspect.getsource(spec.fn))
        except (OSError, TypeError, SyntaxError):
            continue
        if squashes and "drop_reason" not in src:
            bad.append(kind)
    return bad


def main() -> int:
    bad = check_reason_coverage()
    if bad:
        print("reason-coverage FAILED — tiles that can squash pred but "
              "never set carrier['drop_reason']:")
        for k in bad:
            print(f"  {k}")
        return 1
    print("reason-coverage OK: every squashing tile attributes a reason")

    # a replicated topology must keep coverage through the RSS lowering:
    # the clones' lane dispatch wraps the base kind's function, so the
    # per-topology check resolves groups back to that kind
    from repro.apps import echo
    from repro.net.stack import replicated_udp_topology
    topo = replicated_udp_topology([echo.make(port=7)], n_rx=2)
    tbad = check_topology_coverage(topo)
    if tbad:
        print("replicated-topology coverage FAILED:")
        for k in tbad:
            print(f"  {k}")
        return 1
    print(f"replicated-topology coverage OK: {topo.name} "
          f"(groups: {sorted(topo.replica_groups)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
