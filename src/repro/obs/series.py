"""Device-resident time-series ring: per-window counter *deltas*.

PR 7's telemetry tables are cumulative totals — fine for a post-mortem,
useless for "is the drop *rate* spiking right now".  This module keeps a
small ring of per-window deltas directly in the ``run_stream`` carry:

    ring : (NUM_WINDOWS, num_nodes, NUM_METRICS) int32

Metrics per node per window:

    M_FRAMES  frames entering the stage this window
    M_DROPS   frames dropped at the stage this window
    M_BYTES   payload bytes entering the stage this window
    M_P99     occupancy p99 *bucket index* over this window's histogram
              delta (power-of-two buckets, see :mod:`repro.obs.flight`)
    M_RETX    TCP retransmissions this window (tcp_rx rows only)

A "window" is ``win_len`` batches; ``win_len`` is runtime state (set via
``OP_SLO_SET`` with target=-1) so the cadence can be retuned live, no
retrace.  One :func:`update` call per batch does the whole job: add this
batch's per-stage sums into ``cum``, and — when the window closes — one
subtraction (``cum - prev``) plus one scatter into the ring.

Everything here runs inside the scan: fixed shapes, no host callbacks.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import flight

NUM_WINDOWS = 64
M_FRAMES, M_DROPS, M_BYTES, M_P99, M_RETX = range(5)
NUM_METRICS = 5
METRICS = ("frames", "drops", "bytes", "occ_p99", "retx")
METRIC_IDS = {n: i for i, n in enumerate(METRICS)}
DEFAULT_WIN = 8                 # batches per window (runtime-tunable)


def make_series(num_nodes: int, windows: int = NUM_WINDOWS):
    """Fresh series state (device arrays, lives in telemetry["series"])."""
    return {
        "win_len": jnp.asarray(DEFAULT_WIN, jnp.int32),   # runtime knob
        "win_ctr": jnp.asarray(0, jnp.int32),             # batches so far
        "wr": jnp.asarray(0, jnp.int32),                  # windows closed
        "ring": jnp.zeros((windows, num_nodes, NUM_METRICS), jnp.int32),
        "cum": jnp.zeros((num_nodes, NUM_METRICS), jnp.int32),
        "prev": jnp.zeros((num_nodes, NUM_METRICS), jnp.int32),
        "hprev": jnp.zeros((num_nodes + 1, flight.NUM_BUCKETS), jnp.int32),
    }


def p99_bucket(hdelta):
    """Per-row p99 bucket index of a (rows, NUM_BUCKETS) histogram delta.

    Smallest bucket b with cumsum(b) >= 0.99 * total; 0 for empty rows.
    """
    cum = jnp.cumsum(hdelta, axis=1)
    total = cum[:, -1:]
    ge = cum.astype(jnp.float32) >= 0.99 * total.astype(jnp.float32)
    idx = jnp.argmax(ge, axis=1).astype(jnp.int32)
    return jnp.where(total[:, 0] > 0, idx, 0)


def update(series, frames, drops, bytes_, retx, histo):
    """One per-batch step: accumulate, and close a window when due.

    frames/drops/bytes_/retx: (num_nodes,) int32 per-stage sums for this
    batch (retx is cumulative — deltas fall out of the cum-prev
    subtraction like everything else).  histo: the *cumulative*
    (num_nodes+1, NUM_BUCKETS) occupancy histogram after this batch.
    """
    ser = dict(series)
    add = jnp.stack([frames, drops, bytes_,
                     jnp.zeros_like(frames), retx], axis=1)
    cum = ser["cum"] + add.astype(jnp.int32)
    # retx arrives as a cumulative total, not a per-batch increment:
    # store it absolutely so cum-prev still yields the window delta.
    cum = cum.at[:, M_RETX].set(retx.astype(jnp.int32))

    ctr = ser["win_ctr"] + 1
    close = ctr >= ser["win_len"]

    # the close path (p99 reduction, ring scatter, snapshots) only runs
    # on the 1-in-win_len batch that actually closes a window
    def _close(_):
        row = cum - ser["prev"]
        hdelta = (histo - ser["hprev"])[: row.shape[0]]
        row = row.at[:, M_P99].set(p99_bucket(hdelta))
        slot = jnp.mod(ser["wr"], ser["ring"].shape[0])
        return ser["ring"].at[slot].set(row), cum, histo

    def _skip(_):
        return ser["ring"], ser["prev"], ser["hprev"]

    ring, prev, hprev = jax.lax.cond(close, _close, _skip, None)
    ser["cum"] = cum
    ser["ring"] = ring
    ser["wr"] = ser["wr"] + close.astype(jnp.int32)
    ser["win_ctr"] = jnp.where(close, jnp.zeros_like(ctr), ctr)
    ser["prev"] = prev
    ser["hprev"] = hprev
    return ser


# ---------------------------------------------------------------- host side

def series_rows(series):
    """Decode the ring oldest-first -> list of (window_idx, ndarray row)."""
    ring = np.asarray(series["ring"])
    wr = int(series["wr"])
    depth = ring.shape[0]
    n = min(wr, depth)
    out = []
    for age in range(n - 1, -1, -1):
        w = wr - 1 - age
        out.append((w, ring[w % depth]))
    return out


def last_window(series):
    """Newest completed window as (window_idx, (num_nodes, M) ndarray)."""
    rows = series_rows(series)
    return rows[-1] if rows else (None, None)
