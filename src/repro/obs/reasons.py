"""Drop-reason registry: the answer to "which tile dropped this frame and
why".

Every tile that can reject a packet attributes the rejection to one of
these codes by writing it into ``carrier["drop_reason"]`` for the rows it
failed (the executor zeroes the field before each stage, so a code always
names the stage that set it).  The executor folds the codes into a
per-tile ``(reason -> count)`` table — ``telemetry["drops"]``, shape
``(num_nodes, NUM_REASONS)`` — with one fused add per batch, and the
management plane serves rows of it over ``DROP_READ``.

Two kinds of attribution share the table:

  * **hard drops** — the tile returned ``ok=False`` for the row, so the
    packet leaves the pipeline.  A hard drop with no specific code is
    counted under :data:`UNSPEC` (so drops can never disappear from the
    table, only lack detail).
  * **soft drops** — the tile answered the request with an error instead
    of dropping the frame (e.g. ``lm_serve``'s ERR_* sentinel replies).
    The frame stays alive but the rejection is still attributed.

Codes are stable wire values (DROP_READ responses carry counts by index);
append new codes, never renumber.
"""
from __future__ import annotations

NONE = 0               # not dropped
UNSPEC = 1             # dropped with no tile-specific attribution

# ip_rx (ipv4.parse)
IP_VERSION = 2         # version != 4
IP_CSUM = 3            # header checksum mismatch
IP_TTL = 4             # ttl == 0
IP_LEN = 5             # total_len exceeds the received bytes

# udp_rx (udp.parse + rpc.parse + dispatch rate limiting)
RUNT_UDP = 6           # udp_len < 8: header shorter than itself
UDP_LEN = 7            # udp_len exceeds the ip payload
UDP_CSUM = 8           # checksum present and wrong
RPC_MAGIC = 9          # rpc frame magic mismatch
RPC_LEN = 10           # rpc payload_len exceeds the datagram
RATE_LIMIT = 11        # per-port token bucket exhausted

# tcp_rx
TCP_NO_CONN = 12       # no connection-table match and not a SYN

# app tiles (soft drops: error replies, request not served)
APP_BAD_REQ = 13       # malformed / truncated / too-narrow request
APP_NO_SESSION = 14    # unknown session id
APP_NO_SLOT = 15       # session table full / session out of room

# ipinip_decap
IPIP_BAD = 16          # outer header not a decapsulatable IP-in-IP frame

NUM_REASONS = 24       # fixed table width (wire format; room to grow)

NAMES = {
    NONE: "none", UNSPEC: "unspec",
    IP_VERSION: "ip_version", IP_CSUM: "ip_csum", IP_TTL: "ip_ttl",
    IP_LEN: "ip_len",
    RUNT_UDP: "runt_udp", UDP_LEN: "udp_len", UDP_CSUM: "udp_csum",
    RPC_MAGIC: "rpc_magic", RPC_LEN: "rpc_len", RATE_LIMIT: "rate_limit",
    TCP_NO_CONN: "tcp_no_conn",
    APP_BAD_REQ: "app_bad_req", APP_NO_SESSION: "app_no_session",
    APP_NO_SLOT: "app_no_slot",
    IPIP_BAD: "ipip_bad",
}


def name(code: int) -> str:
    return NAMES.get(code, f"reason_{code}")
