"""Host-side postcard/alert collector.

The device pushes two kinds of frames at egress (see
:mod:`repro.obs.postcard` and :mod:`repro.obs.slo` for the wire
formats): per-sampled-frame telemetry postcards and edge-triggered SLO
alerts.  This module is the receive side an operator would run next to
the NIC: decode the frames, reassemble per-flow per-hop paths, and merge
the postcard slices into the same Chrome/Perfetto trace-event stream the
pull-side exporter (:mod:`repro.obs.export`) produces — one combined
timeline from both halves.
"""
from __future__ import annotations

import json
import struct
from typing import Dict, List, Optional, Sequence

from repro.net import rpc
from repro.obs import export, postcard, reasons, series

ETH_HLEN, IP_HLEN, UDP_HLEN = 14, 20, 8


def _rpc_body(frame: bytes, msg_type: int, dst_port: Optional[int] = None):
    """Strip Eth/IPv4/UDP/RPC; return (body, req_id) or None."""
    rpc_off = ETH_HLEN + IP_HLEN + UDP_HLEN
    if len(frame) < rpc_off + rpc.HLEN:
        return None
    if dst_port is not None:
        (dport,) = struct.unpack_from("!H", frame, ETH_HLEN + IP_HLEN + 2)
        if dport != dst_port:
            return None
    magic, mt, req_id, plen = struct.unpack_from("!HBIH", frame, rpc_off)
    if magic != rpc.MAGIC or mt != msg_type:
        return None
    body = frame[rpc_off + rpc.HLEN: rpc_off + rpc.HLEN + plen]
    if len(body) < plen:
        return None
    return body, req_id


def decode_postcard(frame: bytes) -> Optional[Dict]:
    """One postcard frame -> dict, or None if it isn't one."""
    got = _rpc_body(frame, rpc.MSG_POSTCARD)
    if got is None:
        return None
    body, _ = got
    if len(body) < postcard.HDR_BYTES or body[0] != postcard.VERSION:
        return None
    nhops = body[1]
    if len(body) < postcard.body_bytes(nhops):
        return None
    fid, step, sip, dip, sport, dport = struct.unpack_from("!IIIIHH", body, 4)
    hops = []
    for i in range(nhops):
        off = postcard.HDR_BYTES + postcard.HOP_BYTES * i
        stage, visited, occb = body[off], body[off + 1], body[off + 2]
        enter, exit_ = struct.unpack_from("!II", body, off + 4)
        hops.append({"stage": stage, "visited": bool(visited),
                     "occ_bucket": occb, "enter": enter, "exit": exit_})
    return {"frame_id": fid, "step": step,
            "flow": (sip, dip, sport, dport),
            "first_reason": body[2], "dropped": bool(body[3] & 1),
            "hops": hops}


def decode_alert(frame: bytes) -> Optional[Dict]:
    """One MSG_ALERT frame -> dict, or None if it isn't one."""
    got = _rpc_body(frame, rpc.MSG_ALERT)
    if got is None:
        return None
    body, _ = got
    if len(body) < 16 or body[0] != postcard.VERSION:
        return None
    value, thr, window = struct.unpack_from("!III", body, 4)
    mi = body[2]
    return {"rule": body[1],
            "metric": series.METRICS[mi] if mi < len(series.METRICS)
            else mi,
            "node": body[3], "value": value, "threshold": thr,
            "window": window}


def harvest(payloads, lengths, valid) -> List[bytes]:
    """Pull the valid frames out of stacked (..., B, W) egress arrays
    (e.g. the ``pc_*`` / ``alert_*`` outs of ``run_stream``)."""
    import numpy as np
    p = np.asarray(payloads).reshape(-1, payloads.shape[-1])
    l = np.asarray(lengths).reshape(-1)
    v = np.asarray(valid).reshape(-1)
    return [bytes(p[i, :l[i]].astype(np.uint8)) for i in range(p.shape[0])
            if v[i]]


def flow_paths(cards: Sequence[Dict],
               order: Sequence[str]) -> Dict[tuple, List[Dict]]:
    """Group decoded postcards into per-flow hop paths: {flow: [{frame_id,
    path (visited stage names), first_reason, dropped}, ...]}."""
    out: Dict[tuple, List[Dict]] = {}
    for c in cards:
        path = [order[h["stage"]] if h["stage"] < len(order)
                else f"node{h['stage']}"
                for h in c["hops"] if h["visited"]]
        out.setdefault(c["flow"], []).append({
            "frame_id": c["frame_id"], "path": path,
            "first_reason": reasons.name(c["first_reason"]),
            "dropped": c["dropped"]})
    return out


def to_trace_events(cards: Sequence[Dict],
                    order: Sequence[str]) -> List[Dict]:
    """Postcards as Chrome trace-event slices, same shape as the pull
    exporter's (pid 1 = the postcard collector, tid = frame id)."""
    events: List[Dict] = []
    seen = set()
    for c in cards:
        tid = c["frame_id"]
        if tid not in seen:
            seen.add(tid)
            sip, dip, sp, dp = c["flow"]
            label = f"frame {tid} flow {sip:#x}:{sp}->{dip:#x}:{dp}"
            if c["first_reason"]:
                label += f" [{reasons.name(c['first_reason'])}]"
            events.append({"ph": "M", "name": "thread_name", "pid": 1,
                           "tid": tid, "args": {"name": label}})
        for h in c["hops"]:
            if not h["visited"]:
                continue
            i = h["stage"]
            events.append({
                "ph": "X", "pid": 1, "tid": tid,
                "name": order[i] if i < len(order) else f"node{i}",
                "ts": h["enter"], "dur": h["exit"] - h["enter"],
                "args": {"step": c["step"],
                         "occ_bucket": h["occ_bucket"]},
            })
    return events


def write_perfetto(path: str, cards: Sequence[Dict], order: Sequence[str],
                   state=None, pipeline=None) -> int:
    """Write postcards (and, when a state/pipeline is given, the pull-side
    flight recorder too) as one combined Perfetto trace."""
    events = [{"ph": "M", "name": "process_name", "pid": 1,
               "args": {"name": "beehive-postcards"}}]
    events += to_trace_events(cards, order)
    if state is not None and pipeline is not None:
        events.append({"ph": "M", "name": "process_name", "pid": 0,
                       "args": {"name": "beehive-pipeline"}})
        events += export.to_trace_events(state["telemetry"]["obs"],
                                         pipeline.order)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ns"}, f)
    return len(events)
