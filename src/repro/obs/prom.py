"""Prometheus text exposition of the device-resident series ring.

Renders the newest completed windows of ``telemetry["series"]`` (plus
the rule table, when present) in the Prometheus text format — the host
half of the push pipeline an operator would mount behind ``/metrics``.
Rates are per *window* (``win_len`` batches); the window length is
exported too so a scraper can normalise to per-second.

No device computation: one ``np.asarray`` per table at entry.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import series

_HELP = {
    "frames": "frames entering the stage per window",
    "drops": "frames dropped at the stage per window",
    "bytes": "payload bytes entering the stage per window",
    "occ_p99": "occupancy p99 power-of-two bucket index per window",
    "retx": "TCP retransmissions per window",
}


def _fmt(name: str, labels: Dict[str, object], value) -> str:
    lbl = ",".join(f'{k}="{v}"' for k, v in labels.items())
    return f"beehive_{name}{{{lbl}}} {int(value)}"


def render(ser: Dict, order: Sequence[str], slo=None,
           windows: int = 1, shard: Optional[int] = None) -> str:
    """Text exposition of the last `windows` completed windows.  A
    non-None ``shard`` adds a ``shard="<s>"`` label to every sample so a
    scraper can aggregate or slice across a sharded dataplane."""
    extra = {} if shard is None else {"shard": shard}
    lines: List[str] = []
    rows = series.series_rows(ser)[-max(1, windows):]
    lines.append(f"# HELP beehive_window_len_batches batches per "
                 f"series window")
    lines.append("# TYPE beehive_window_len_batches gauge")
    lines.append(_fmt("window_len_batches", dict(extra),
                      int(ser["win_len"])))
    for mi, mname in enumerate(series.METRICS):
        lines.append(f"# HELP beehive_window_{mname} {_HELP[mname]}")
        lines.append(f"# TYPE beehive_window_{mname} gauge")
        for w, row in rows:
            row = np.asarray(row)
            for ni in range(row.shape[0]):
                node = order[ni] if ni < len(order) else f"node{ni}"
                lines.append(_fmt(f"window_{mname}",
                                  {"node": node, "window": w, **extra},
                                  row[ni, mi]))
    if slo is not None:
        active = np.asarray(slo["active"])
        lines.append("# HELP beehive_slo_active rule is currently latched")
        lines.append("# TYPE beehive_slo_active gauge")
        for r in range(active.shape[0]):
            lines.append(_fmt("slo_active", {"rule": r, **extra},
                              active[r]))
        lines.append("# HELP beehive_slo_alerts_total alert edges emitted")
        lines.append("# TYPE beehive_slo_alerts_total counter")
        lines.append(_fmt("slo_alerts_total", dict(extra),
                          int(slo["alerts"])))
    return "\n".join(lines) + "\n"


def render_state(state: Dict, pipeline, windows: int = 1,
                 shard: Optional[int] = None) -> str:
    """Convenience wrapper over a full stack state."""
    return render(state["telemetry"]["series"], pipeline.order,
                  slo=state.get("slo"), windows=windows, shard=shard)


def render_sharded(state: Dict, pipeline, windows: int = 1) -> str:
    """Exposition of a `ShardedStream` state (leading shard axis on
    every leaf): one labeled block per shard, de-duplicated HELP/TYPE
    headers, ready to mount behind one ``/metrics`` endpoint."""
    import jax
    shards = jax.tree.leaves(state)[0].shape[0]
    lines: List[str] = []
    seen = set()
    for s in range(shards):
        view = jax.tree.map(lambda x: x[s], state)
        for ln in render_state(view, pipeline, windows=windows,
                               shard=s).splitlines():
            if ln.startswith("#"):
                if ln in seen:
                    continue
                seen.add(ln)
            lines.append(ln)
    return "\n".join(lines) + "\n"
