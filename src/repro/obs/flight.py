"""Sampled packet flight recorder + in-device latency histograms.

All state lives under ``telemetry["obs"]`` in the stack state pytree, so
it rides the ``run_stream`` scan carry like every other table — recording
is pure jnp, zero host callbacks, and the whole facility is donated along
with the rest of the state.

Flight-recorder row layout (int32, width ``4 + 2 * num_nodes``)::

    [frame_id, step, visit_bitmap, drop_reason,
     enter_0, exit_0, enter_1, exit_1, ...]

``frame_id`` is a monotonically increasing per-frame counter (survives
across batches and stream windows), ``step`` the batch counter,
``visit_bitmap`` bit i set iff the frame arrived at execution node i,
``drop_reason`` the first :mod:`repro.obs.reasons` code attributed to the
frame (0 = delivered).  ``enter_i``/``exit_i`` are cycle estimates on the
NoC cost model: a frame enters node i at the node's compile-time chain
latency plus its position in the batch's arrival queue at that node, and
occupies the node for one service slot — so enter/exit vary per frame
with real traffic (queueing), not just per topology.

Sampling: a frame is recorded iff ``enable != 0`` and ``frame_id % N ==
0`` with ``N = 2**shift``.  Both knobs are *runtime state* (``ctrl``),
rewritable live by the management plane's ``TRACE_SET`` — no retrace.

Histograms: fixed power-of-two buckets (bucket k counts values v with
``2**k <= v < 2**(k+1)``; bucket 0 additionally catches v <= 1).  One row
per node of per-stage *occupancy* (queue position + service: what the
frame saw at that tile) plus one end-to-end row (ingress enter to the
last visited node's exit), accumulated for every frame of every batch
with one fused add — p50/p99 come straight from device state.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.core import telemetry

TRACE_ENTRIES = 256    # flight-recorder ring depth
NUM_BUCKETS = 16       # power-of-two histogram buckets
MAX_NODES = 28         # visit bitmap must fit an int32 alongside nothing
DEFAULT_SHIFT = 6      # 1-in-64 sampling when first enabled
FIXED_WORDS = 4        # row words before the per-node enter/exit pairs


def trace_width(num_nodes: int) -> int:
    return FIXED_WORDS + 2 * num_nodes


def make_obs(num_nodes: int,
             trace_entries: int = TRACE_ENTRIES) -> Dict:
    """The ``telemetry["obs"]`` block for a pipeline of `num_nodes`
    stages.  Recorder starts disabled; histograms are recorded whenever
    the recorder is enabled."""
    if num_nodes > MAX_NODES:
        raise ValueError(
            f"flight recorder supports at most {MAX_NODES} execution "
            f"nodes (visit bitmap is one int32); got {num_nodes}")
    return {
        "ctrl": {"enable": jnp.zeros((), jnp.int32),
                 "shift": jnp.full((), DEFAULT_SHIFT, jnp.int32)},
        "frame_ctr": jnp.zeros((), jnp.int32),
        "trace": telemetry.RingLog(
            entries=jnp.zeros((trace_entries, trace_width(num_nodes)),
                              jnp.int32),
            wr=jnp.zeros((), jnp.int32),
            req_fill=jnp.zeros((), jnp.int32)),
        # per-stage occupancy rows (num_nodes) + one end-to-end row
        "histo": jnp.zeros((num_nodes + 1, NUM_BUCKETS), jnp.int32),
    }


def bucket_of(v: jnp.ndarray) -> jnp.ndarray:
    """Power-of-two bucket index of positive int values (vectorized)."""
    v = jnp.maximum(v.astype(jnp.int32), 1)
    b = jnp.floor(jnp.log2(v.astype(jnp.float32))).astype(jnp.int32)
    return jnp.clip(b, 0, NUM_BUCKETS - 1)


def bucket_counts(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """(NUM_BUCKETS,) histogram of `values` where `mask` (one batch)."""
    b = bucket_of(values)
    hot = (b[:, None] == jnp.arange(NUM_BUCKETS)[None, :]) & mask[:, None]
    return hot.sum(axis=0, dtype=jnp.int32)


def sample_mask(ctrl: Dict, frame_ids: jnp.ndarray) -> jnp.ndarray:
    """(B,) bool — which frames of the batch the recorder captures.  The
    1-in-2**shift modulus is computed from runtime state, so TRACE_SET
    changes the rate with no retrace."""
    n_mask = jnp.left_shift(jnp.int32(1), ctrl["shift"]) - 1
    return (ctrl["enable"] != 0) & ((frame_ids & n_mask) == 0)


def bucket_lo(k: int) -> int:
    """Smallest value counted by bucket k (host-side display helper)."""
    return 1 if k == 0 else 2 ** k


def percentile(counts, q: float) -> int:
    """Upper-bound estimate of the q-quantile (q in [0,1]) from one
    histogram row — host-side, for consoles and summaries."""
    import numpy as np
    c = np.asarray(counts, dtype=np.int64)
    total = int(c.sum())
    if total == 0:
        return 0
    cum = np.cumsum(c)
    k = int(np.searchsorted(cum, q * total, side="left"))
    k = min(k, NUM_BUCKETS - 1)
    return 2 ** (k + 1) - 1
