"""Device-resident observability (paper §4.6 made operable).

Three device-side facilities, all living inside the ``run_stream`` scan
with zero host callbacks, plus a host-side exporter:

  * :mod:`repro.obs.reasons` — the drop-reason registry: every tile that
    rejects a packet attributes the drop to a small reason code, and the
    executor accumulates a per-tile ``(reason -> count)`` table in
    telemetry state.
  * :mod:`repro.obs.flight` — the sampled packet flight recorder (per-
    frame trace rows: frame id, tile-visit bitmap, per-stage enter/exit
    cycle estimates) and the fixed power-of-two-bucket latency
    histograms.  Sample rate and enable are *runtime* state — the
    management plane's ``TRACE_SET`` changes them live, no retrace.
  * :mod:`repro.obs.export` — renders captured flight-recorder rows as
    Chrome/Perfetto trace-event JSON and a ``top``-style text summary.
"""
