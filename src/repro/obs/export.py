"""Host-side rendering of the device-resident observability state.

Everything here runs *after* the stream: it reads the ``telemetry`` block
of a stack state (flight-recorder ring, drop-reason table, latency
histograms) and renders it as

  * Chrome/Perfetto trace-event JSON (``to_trace_events`` /
    ``write_perfetto``) — one track per sampled frame, one complete
    ("ph": "X") slice per tile visit, so ``chrome://tracing`` or
    ui.perfetto.dev shows each frame walking the pipeline; and
  * a ``top``-style text summary (``summary``) — per-tile packet/drop
    counters, the drop-reason breakdown, and p50/p99 occupancy straight
    from the device histograms.

No device computation happens here; ``jax.device_get`` at entry is the
only transfer.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.obs import flight, reasons


def trace_rows(obs: Dict) -> List[Dict]:
    """Decode the flight-recorder ring into per-frame dicts (oldest
    first).  Each row: frame_id, step, visited (node-index list),
    drop_reason, enter/exit (per visited node)."""
    ring = jax.device_get(obs["trace"].entries)
    wr = int(jax.device_get(obs["trace"].wr))
    depth = ring.shape[0]
    nstages = (ring.shape[1] - flight.FIXED_WORDS) // 2
    count = min(wr, depth)
    start = (wr - count) % depth
    out = []
    for k in range(count):
        row = ring[(start + k) % depth]
        bitmap = int(row[2])
        visited = [i for i in range(nstages) if bitmap >> i & 1]
        f = flight.FIXED_WORDS
        out.append({
            "frame_id": int(row[0]),
            "step": int(row[1]),
            "visited": visited,
            "drop_reason": int(row[3]),
            "enter": {i: int(row[f + 2 * i]) for i in visited},
            "exit": {i: int(row[f + 2 * i + 1]) for i in visited},
        })
    return out


def to_trace_events(obs: Dict, order: Sequence[str]) -> List[Dict]:
    """Chrome trace-event list: pid 0 = the pipeline, one tid per sampled
    frame, one complete slice per tile visit (ts/dur in the NoC cycle
    estimate's units)."""
    events: List[Dict] = []
    seen_tids = set()
    for row in trace_rows(obs):
        tid = row["frame_id"]
        if tid not in seen_tids:
            seen_tids.add(tid)
            label = f"frame {tid}"
            if row["drop_reason"]:
                label += f" [{reasons.name(row['drop_reason'])}]"
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": label}})
        for i in row["visited"]:
            events.append({
                "ph": "X", "pid": 0, "tid": tid,
                "name": order[i] if i < len(order) else f"node{i}",
                "ts": row["enter"][i],
                "dur": row["exit"][i] - row["enter"][i],
                "args": {"step": row["step"],
                         "drop_reason": reasons.name(row["drop_reason"])},
            })
    return events


def write_perfetto(path: str, state: Dict, pipeline) -> int:
    """Write the state's flight recorder as a ``.perfetto.json`` trace
    (Chrome trace-event format).  Returns the number of events written."""
    obs = state["telemetry"]["obs"]
    events = to_trace_events(obs, pipeline.order)
    events.insert(0, {"ph": "M", "name": "process_name", "pid": 0,
                      "args": {"name": "beehive-pipeline"}})
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ns"}, f)
    return len(events)


def drop_table(state: Dict, pipeline) -> Dict[str, Dict[str, int]]:
    """{node: {reason_name: count}} for every nonzero cell."""
    tab = np.asarray(jax.device_get(state["telemetry"]["drops"]))
    out: Dict[str, Dict[str, int]] = {}
    for i, nm in enumerate(pipeline.order):
        nz = {reasons.name(r): int(c) for r, c in enumerate(tab[i]) if c}
        if nz:
            out[nm] = nz
    return out


def summary(state: Dict, pipeline, top: int = 5) -> str:
    """``top``-style text panel: per-tile counters from the stacked node
    log's latest row, the drop-reason breakdown, and occupancy p50/p99
    from the device histograms."""
    telem = state["telemetry"]
    lines = [f"{'TILE':<14}{'PKTS':>8}{'DROPS':>8}{'LAT~CYC':>9}"
             f"{'OCC p50':>9}{'OCC p99':>9}"]
    obs = telem.get("obs")
    histo = (np.asarray(jax.device_get(obs["histo"]))
             if obs is not None else None)
    nodes = jax.device_get(telem["nodes"].entries)
    wr = int(jax.device_get(telem["nodes"].wr))
    latest = nodes[(wr - 1) % nodes.shape[0]] if wr else None
    for i, nm in enumerate(pipeline.order):
        pkts, drops, lat = (0, 0, 0)
        if latest is not None:
            pkts, drops, lat = (int(latest[i][1]), int(latest[i][2]),
                                int(latest[i][3]))
        p50 = p99 = "-"
        if histo is not None and histo[i].sum():
            p50 = flight.percentile(histo[i], 0.50)
            p99 = flight.percentile(histo[i], 0.99)
        lines.append(f"{nm:<14}{pkts:>8}{drops:>8}{lat:>9}"
                     f"{str(p50):>9}{str(p99):>9}")
    if histo is not None and histo[-1].sum():
        lines.append(f"{'(end-to-end)':<14}{'':>8}{'':>8}{'':>9}"
                     f"{str(flight.percentile(histo[-1], 0.50)):>9}"
                     f"{str(flight.percentile(histo[-1], 0.99)):>9}")
    per_node = drop_table(state, pipeline)
    if per_node:
        lines.append("")
        lines.append("top drop reasons:")
        flat = [(n, r, c) for n, rs in per_node.items()
                for r, c in rs.items()]
        flat.sort(key=lambda t: -t[2])
        for n, r, c in flat[:top]:
            lines.append(f"  {n:<14}{r:<16}{c:>8}")
    return "\n".join(lines)
