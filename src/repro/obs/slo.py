"""On-device SLO watchdog: threshold rules over the series ring, alerts
pushed in-band.

Rules live in ``state["slo"]`` (fixed-shape arrays — runtime state, set
live via the ``OP_SLO_SET`` management op, no retrace).  Each rule
watches one ``(node, metric)`` cell of the newest completed time-series
window (:mod:`repro.obs.series`) with two thresholds:

    thr_raise   window value >= thr_raise  -> rule becomes active
    thr_clear   window value <= thr_clear  -> rule deactivates

Alerts are *edge-triggered with hysteresis*: an ``MSG_ALERT`` frame is
emitted only on the inactive->active transition, and the rule stays
latched until the value falls to ``thr_clear`` — a 40-window burst
produces one alert, not forty.  Evaluation happens at batch egress
inside the scan (one gather + compares per rule slot); the alert frames
ride the normal egress path like postcards, so the push direction needs
no host callback either.

Alert wire format (RPC body, ``MSG_ALERT``):

    off  size  field
    0    1     version (=1)
    1    1     rule slot index
    2    1     metric id (repro.obs.series.METRICS)
    3    1     node index
    4    4     window value that crossed the threshold
    8    4     thr_raise at evaluation time
    12   4     series window index (req_id repeats it)
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B, rpc
from repro.obs import postcard

NUM_RULES = 8
ALERT_BODY_BYTES = 16


def make_rules(num_rules: int = NUM_RULES):
    """Fresh rule table (device arrays, lives in state["slo"])."""
    z = lambda: jnp.zeros((num_rules,), jnp.int32)
    return {"metric": z(), "node": z(),
            "thr_raise": z(), "thr_clear": z(),
            "enabled": z(), "active": z(),
            "last_wr": jnp.asarray(0, jnp.int32),
            "alerts": jnp.asarray(0, jnp.int32)}


def evaluate(slo_state, ser):
    """One per-batch step: (slo', edge, value).

    Only does real work when a new window closed since the last look
    (``ser["wr"]`` advanced); otherwise rule state passes through
    unchanged and ``edge`` is all-False.
    """
    s = dict(slo_state)
    ring, wr = ser["ring"], ser["wr"]
    W, N, M = ring.shape
    fresh = wr > s["last_wr"]
    row = ring[jnp.mod(wr - 1, W)]                       # newest window
    val = row[jnp.clip(s["node"], 0, N - 1),
              jnp.clip(s["metric"], 0, M - 1)]
    en = (s["enabled"] != 0) & (wr > 0)
    breach = val >= s["thr_raise"]
    clear_ok = val <= s["thr_clear"]
    was = s["active"] != 0
    now = jnp.where(fresh, breach | (was & ~clear_ok), was) & en
    edge = fresh & now & ~was
    s["active"] = now.astype(jnp.int32)
    s["last_wr"] = jnp.maximum(s["last_wr"], wr)
    s["alerts"] = s["alerts"] + edge.sum(dtype=jnp.int32)
    return s, edge, val


def alert_frames(cfg, slo_state, ser, edge, val):
    """Pack the rule table into (R,) MSG_ALERT frames; ``edge`` is the
    per-slot validity mask (only edges are real alerts)."""
    R = edge.shape[0]
    body = jnp.zeros((R, ALERT_BODY_BYTES + postcard.STACK_BYTES), jnp.uint8)
    u = lambda x: x.astype(jnp.uint32)
    win = jnp.broadcast_to(jnp.maximum(ser["wr"] - 1, 0), (R,))
    body = B.set_u8(body, 0, jnp.full((R,), postcard.VERSION, jnp.uint32))
    body = B.set_u8(body, 1, jnp.arange(R, dtype=jnp.uint32))
    body = B.set_u8(body, 2, u(slo_state["metric"]))
    body = B.set_u8(body, 3, u(slo_state["node"]))
    body = B.set_be32(body, 4, u(val))
    body = B.set_be32(body, 8, u(slo_state["thr_raise"]))
    body = B.set_be32(body, 12, u(win))
    blen = jnp.full((R,), ALERT_BODY_BYTES, jnp.int32)
    return postcard.egress_frame(body, blen, rpc.MSG_ALERT, u(win), cfg)


def bind_watchdog(topo, collector_ip=0,
                  collector_port=postcard.DEFAULT_ALERT_PORT,
                  rules: int = NUM_RULES, **params):
    """Add the `watchdog` tile to a topology, fed from eth_tx.

    Widens the mesh by one column.  The data-NoC chain models the alert
    egress; if the topology already carries a ctrl NoC (bind_mgmt), a
    `watchdog.a` endpoint plus a chain to the controller prove the
    in-band alert path deadlock-free on the ctrl NoC too.
    """
    base_x = topo.dim_x
    topo.dim_x = base_x + 1
    p = dict(params)
    p["collector_ip"] = collector_ip
    p["collector_port"] = collector_port
    p["rules"] = rules
    topo.add_tile("watchdog", "watchdog", base_x, 0, params=p)
    topo.add_route("eth_tx", "const", None, "watchdog")
    topo.add_chain("eth_tx", "watchdog")
    bind_alert_path(topo)
    return "watchdog"


def bind_alert_path(topo):
    """Declare the watchdog's in-band alert endpoint + chain on the ctrl
    NoC so the alert path is covered by the ctrl-NoC deadlock analysis.
    Idempotent; a no-op until both a watchdog and a controller exist
    (stacks call this again after ``bind_mgmt``)."""
    if not topo.has_tile("watchdog") or topo.has_tile("watchdog.a"):
        return
    ctrl = next((t.name for t in topo.tiles_on("ctrl")
                 if t.kind == "controller"), None)
    if ctrl is None:
        return
    td = topo.tile("watchdog")
    topo.add_tile("watchdog.a", "mgmt_ep", td.x, td.y + 1, noc="ctrl")
    topo.add_chain("watchdog.a", ctrl)
