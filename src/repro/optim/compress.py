"""Gradient compression for the cross-pod (DCI) hop: int8 quantization with
error feedback.

On a multi-pod mesh the per-step gradient all-reduce crosses the slow
inter-pod links once; quantizing that hop to int8 cuts DCI bytes 4x (fp32)
or 2x (bf16).  Error feedback keeps the quantization *unbiased over time*:
the residual e is added to the next step's gradient before quantizing, so
the series of applied updates telescopes to the true gradient sum
(Karimireddy et al., 2019).

`compressed_psum` runs inside shard_map over the pod axis; within-pod
reduction stays full precision (ICI is fast), only the pod-axis psum sees
int8 payloads.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grad, residual):
    """Error-feedback compress: returns (q, scale, new_residual)."""
    g = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(g)
    new_residual = g - dequantize(q, scale)
    return q, scale, new_residual


def compressed_psum_pod(grads, residuals, mesh, pod_axis: str = "pod"):
    """All-reduce `grads` across the pod axis with int8 payloads + error
    feedback.  grads/residuals: matching pytrees already reduced within the
    pod (standard GSPMD handles the intra-pod part)."""

    def one(g, r):
        def body(gl, rl):
            q, scale, new_r = ef_compress(gl, rl)
            qs = jax.lax.psum(q.astype(jnp.int32), pod_axis)
            ss = jax.lax.psum(scale, pod_axis)
            n = jax.lax.psum(jnp.ones(()), pod_axis)
            # average of dequantized contributions (scales averaged)
            return (qs.astype(jnp.float32) * (ss / n) / n).astype(g.dtype), \
                new_r
        spec = P()  # grads replicated across pod; shard_map over pod only
        from repro.launch.compat import shard_map
        return shard_map(body, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(spec, spec))(g, r)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r, _ = jax.tree_util.tree_flatten(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        ng, nr = one(g, r)
        out_g.append(ng)
        out_r.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_r))


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
