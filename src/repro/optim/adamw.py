"""AdamW with global-norm clipping and linear-warmup/cosine schedule.

Optimizer state is a pytree shaped like params (m, v) plus a scalar step;
m/v inherit each param's dtype by default (fp32 params -> fp32 state;
bf16 params -> bf16 state, used by the 400B config to fit HBM — documented
in EXPERIMENTS.md).  Sharding of m/v follows params exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Optional[str] = None   # None -> match param dtype


def init(params, cfg: AdamWConfig = AdamWConfig()):
    def zeros(p):
        dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state, params, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g32 * (1.0 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + jnp.square(g32) * (1.0 - cfg.b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
