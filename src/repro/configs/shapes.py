"""Assigned input shapes.  Every architecture runs all shapes it supports:

  train_4k     seq 4096,   global_batch 256   (train_step)
  prefill_32k  seq 32768,  global_batch 32    (prefill_step)
  decode_32k   seq 32768,  global_batch 128   (decode_step, cache of seq_len)
  long_500k    seq 524288, global_batch 1     (decode_step; sub-quadratic archs)
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str      # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    """Assignment skip rules (documented in DESIGN.md §Arch-applicability)."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False  # encoder-only archs have no decode step
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False  # pure full-attention archs skip 500k decode
    return True


def grid(cfg: ModelConfig):
    return [s for s in SHAPES.values() if applicable(cfg, s)]
