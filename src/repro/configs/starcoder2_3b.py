"""starcoder2-3b [dense] — 30L d=3072 24H (GQA kv=2) ff=12288 vocab=49152,
GQA + RoPE, biases on all linears, non-gated GeLU MLP.  [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", n_layers=30, d_model=3072, vocab=49152,
    n_heads=24, n_kv_heads=2, head_dim=128, qkv_bias=True, o_bias=True,
    d_ff=12288, gated_mlp=False, mlp_bias=True, activation="gelu",
    pattern=("g",), rope_theta=999_999.44,
    tie_embeddings=True, supports_long_context=False,
)
