"""gemma3-12b [dense] — 48L d=3840 16H (GQA kv=8) ff=15360 vocab=262144,
5:1 local:global attention, 128k context.  [hf:google/gemma-3; unverified]

Faithful points: head_dim=256 (explicit, != d/H), qk-norm, gemma GeGLU MLP,
sqrt(d) embedding scaling, 1024-token local window, pattern LLLLLG.
Simplification: single rope_theta (1e6) for both local and global layers.
long_500k applicable: 40/48 layers are window-bounded; the 8 global-layer
caches shard over the mesh (see DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", n_layers=48, d_model=3840, vocab=262144,
    n_heads=16, n_kv_heads=8, head_dim=256, qk_norm=True,
    d_ff=15360, activation="gelu", pattern=("l", "l", "l", "l", "l", "g"),
    window=1024, rope_theta=1_000_000.0, embed_scale=True,
    tie_embeddings=True, supports_long_context=True,
)
