"""olmoe-1b-7b [moe] — 16L d=2048 16H (kv=16) vocab=50304, MoE 64 experts
top-8, d_ff_expert=1024, qk-norm.  [arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", n_layers=16, d_model=2048, vocab=50304,
    n_heads=16, n_kv_heads=16, head_dim=128, qk_norm=True,
    pattern=("g:moe",), n_experts=64, top_k=8, d_ff_expert=1024,
    router="softmax", rope_theta=10_000.0,
    tie_embeddings=False, supports_long_context=False,
)
