"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (GQA kv=8) vocab=202048,
MoE 128 experts top-1 + shared expert, alternating dense/MoE layers
(interleave step 2, as released).  [hf:meta-llama/Llama-4; unverified]

d_ff_expert=8192 per the assignment; interleaved dense layers use 16384
(2x), matching the released Maverick geometry and the 400B-total /
17B-active parameter budget.  Sigmoid router (llama4-style).
param/opt dtype bf16 so that params+Adam state fit 16 GiB/chip HBM on a
v5e-256 pod (documented in EXPERIMENTS.md §Dry-run)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", n_layers=48, d_model=5120,
    vocab=202048, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384, pattern=("g", "g:moe"),
    n_experts=128, top_k=1, d_ff_expert=8192, shared_expert=True,
    router="sigmoid", rope_theta=500_000.0,
    tie_embeddings=False, supports_long_context=False,
    param_dtype="bfloat16",
)
