"""internvl2-2b [vlm] — InternViT frontend + InternLM2-1.8b backbone,
24L d=2048 16H (GQA kv=8) ff=8192 vocab=92553.  [arXiv:2404.16821; hf]

The ViT is a STUB per the assignment: input_specs() provides precomputed
patch embeddings (B, 256, D) spliced over the first 256 token positions.
vocab 92553 is padded to 92672 for TP-16 divisibility (loss masks the pad)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", n_layers=24, d_model=2048, vocab=92553,
    n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, pattern=("g",), rope_theta=1_000_000.0,
    frontend="vision_stub", n_image_embeds=256,
    tie_embeddings=False, supports_long_context=False,
)
