"""falcon-mamba-7b [ssm] — 64L d=4096 attention-free Mamba-1,
d_inner=8192, ssm_state=16, dt_rank=256, vocab=65024.
[arXiv:2410.05355; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", n_layers=64, d_model=4096, vocab=65024,
    pattern=("m",), d_inner=8192, ssm_state=16, dt_rank=256, conv_width=4,
    tie_embeddings=False, supports_long_context=True,
)
