"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduced
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, grid

_MODULES = {
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "gemma3-12b": "gemma3_12b",
    "starcoder2-3b": "starcoder2_3b",
    "internlm2-1.8b": "internlm2_1_8b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "hubert-xlarge": "hubert_xlarge",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-2b": "internvl2_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str, **over) -> ModelConfig:
    return reduced(get_config(arch), **over)
