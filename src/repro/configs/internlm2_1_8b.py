"""internlm2-1.8b [dense] — 24L d=2048 16H (GQA kv=8) ff=8192 vocab=92544.
[arXiv:2403.17297; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b", n_layers=24, d_model=2048, vocab=92544,
    n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, pattern=("g",), rope_theta=1_000_000.0,
    tie_embeddings=False, supports_long_context=False,
)
