"""hubert-xlarge [audio] — 48L d=1280 16H ff=5120 vocab=504, encoder-only
(bidirectional, no decode).  The conv waveform frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings (B, T, D).
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", n_layers=48, d_model=1280, vocab=504,
    n_heads=16, n_kv_heads=16, head_dim=80, causal=False,
    d_ff=5120, gated_mlp=False, activation="gelu", pattern=("g",),
    frontend="audio_stub", tie_embeddings=False,
    supports_decode=False, supports_long_context=False,
)
