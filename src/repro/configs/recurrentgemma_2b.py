"""recurrentgemma-2b [hybrid] — 26L d=2560 10H (MQA kv=1) ff=7680
vocab=256000, RG-LRU + local attention 1:2 (pattern RRL), window 2048.
[arXiv:2402.19427; hf]

26 = 8 full (r,r,l) units + 2 remainder recurrent layers (explicit)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", n_layers=26, d_model=2560, vocab=256000,
    n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, activation="gelu", pattern=("r", "r", "l"), window=2048,
    lru_width=2560, conv_width=4, rope_theta=10_000.0, embed_scale=True,
    tie_embeddings=True, supports_long_context=True,
)
