"""The direct-attached serving cell: which model backs the LM tile in the
serving benchmarks/tests, and the session-capacity knobs shared by the
host-mediated baseline and the compiled-stack path.

A *global-attention* architecture is required here: the direct tile (like
`ServeEngine.step`) runs one decode step over every session slot and masks
the position/token updates for sessions that did not advance — sound for
position-indexed KV caches (the spurious write lands at the stale `pos`
and is overwritten by the session's next real step), but not for
recurrent/rolling states, which mutate unconditionally.
"""
from __future__ import annotations

from repro.configs import get_smoke_config

SERVE_ARCH = "qwen1.5-0.5b"     # smallest attention arch in the registry
MAX_SESSIONS = 4
MAX_SEQ = 64
LM_TILE = "lm"
RS_TILE = "rs"


def serve_config(**over):
    """The reduced-size serving model used by bench_rpc_tail and the
    serving tests (same family as the full arch, CPU-smoke shapes)."""
    return get_smoke_config(SERVE_ARCH, **over)
