"""Pallas TPU kernel: Reed-Solomon (k, p) parity generation over GF(256).

Bit-plane formulation: for parity row j,
    parity_j = XOR_i XOR_b ( ((data_i >> b) & 1) * bp[j, i, b] )
— pure AND/shift/multiply/XOR vector ops on the VPU; no table gathers
(TPU has no efficient byte-gather; the FPGA's LUT multipliers become
bit-plane linear maps — see DESIGN.md hardware-adaptation notes).

Block layout: data (k, N) uint8 is tiled along N into (k, BLK) VMEM blocks
(k=8, BLK=4096 -> 32 KiB in + 8 KiB out per step, MXU-free VPU work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK = 4096


def _rs_kernel(bp_ref, data_ref, out_ref, *, k: int, p: int):
    data = data_ref[...]                      # (k, BLK) uint8
    bp = bp_ref[...]                          # (p, k, 8) uint8
    acc = jnp.zeros((p,) + data.shape[1:], jnp.uint8)
    for j in range(p):
        row = jnp.zeros(data.shape[1:], jnp.uint8)
        for i in range(k):
            x = data[i]
            for b in range(8):
                bit = (x >> b) & jnp.uint8(1)
                row = row ^ (bit * bp[j, i, b])
        acc = acc.at[j].set(row)
    out_ref[...] = acc


def rs_encode_pallas(data, bitplanes, *, block: int = BLK,
                     interpret: bool = True):
    """data: (k, N) uint8; bitplanes: (p, k, 8) uint8 -> (p, N) uint8."""
    k, N = data.shape
    p = bitplanes.shape[0]
    assert N % block == 0, (N, block)
    grid = (N // block,)
    return pl.pallas_call(
        functools.partial(_rs_kernel, k=k, p=p),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, k, 8), lambda n: (0, 0, 0)),
            pl.BlockSpec((k, block), lambda n: (0, n)),
        ],
        out_specs=pl.BlockSpec((p, block), lambda n: (0, n)),
        out_shape=jax.ShapeDtypeStruct((p, N), jnp.uint8),
        interpret=interpret,
    )(bitplanes, data)
