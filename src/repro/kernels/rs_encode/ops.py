"""Public op: jit'd RS encode with kernel/oracle selection."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rs_encode import gf
from repro.kernels.rs_encode.kernel import BLK, rs_encode_pallas
from repro.kernels.rs_encode.ref import rs_encode_jnp


@functools.lru_cache(maxsize=None)
def _mats(k: int, p: int):
    gm = gf.generator_matrix(k, p)
    return gm, gf.bitplane_matrix(gm)


@functools.partial(jax.jit, static_argnames=("k", "p", "use_pallas", "block"))
def rs_encode(data, k: int = 8, p: int = 2, use_pallas: bool = True,
              block: int = BLK):
    """data: (k, N) uint8 -> parity (p, N) uint8 for RS(k+p, k)."""
    gm, bp = _mats(k, p)
    if use_pallas:
        return rs_encode_pallas(data, jnp.asarray(bp), block=block)
    return rs_encode_jnp(data, gm)


def encode_blocks(blocks, k: int = 8, p: int = 2, use_pallas: bool = True):
    """blocks: (B, k*S) uint8 request payloads -> (B, p*S) parity, i.e. the
    paper's 4 KiB-in / 1 KiB-out RS(8,2) app semantics."""
    B, total = blocks.shape
    S = total // k
    data = blocks.reshape(B, k, S).transpose(1, 0, 2).reshape(k, B * S)
    pad = (-data.shape[1]) % BLK
    if pad and use_pallas:
        data = jnp.pad(data, ((0, 0), (0, pad)))
    parity = rs_encode(data, k=k, p=p, use_pallas=use_pallas)
    parity = parity[:, :B * S].reshape(p, B, S).transpose(1, 0, 2)
    return parity.reshape(B, p * S)
