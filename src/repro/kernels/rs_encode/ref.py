"""Pure-jnp / numpy oracle for RS encoding (log/antilog table method)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.rs_encode import gf


def rs_encode_np(data: np.ndarray, gm: np.ndarray) -> np.ndarray:
    """data: (k, N) uint8, gm: (p, k) -> (p, N). Classic table method."""
    p, k = gm.shape
    out = np.zeros((p, data.shape[1]), np.uint8)
    for j in range(p):
        acc = np.zeros(data.shape[1], np.uint8)
        for i in range(k):
            acc ^= gf.gf_mul_vec(data[i], int(gm[j, i]))
        out[j] = acc
    return out


def rs_encode_jnp(data, gm_np: np.ndarray):
    """jnp oracle using the same bit-plane math (validates the formulation
    independent of Pallas)."""
    bp = jnp.asarray(gf.bitplane_matrix(gm_np))   # (p, k, 8)
    p, k, _ = bp.shape
    out = []
    for j in range(p):
        row = jnp.zeros(data.shape[1:], jnp.uint8)
        for i in range(k):
            for b in range(8):
                bit = (data[i] >> b) & jnp.uint8(1)
                row = row ^ (bit * bp[j, i, b])
        out.append(row)
    return jnp.stack(out)
