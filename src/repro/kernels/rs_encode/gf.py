"""GF(256) arithmetic (poly 0x11D) + Reed-Solomon generator matrices.

Host-side (numpy) table construction; the device kernel uses the
*bit-plane* representation: multiply-by-constant c over GF(2^8) is linear
over GF(2), so y = XOR_b [ ((x >> b) & 1) * (c * 2^b) ] — eight AND/XOR
vector ops per coefficient, no gathers.  This is the TPU-native
re-formulation of the FPGA's LUT-based GF multipliers (DESIGN.md).
"""
from __future__ import annotations

import numpy as np

POLY = 0x11D


def _build_tables():
    exp = np.zeros(512, np.int32)
    log = np.zeros(256, np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= POLY
    exp[255:510] = exp[:255]
    return exp, log


EXP, LOG = _build_tables()


def gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(EXP[LOG[a] + LOG[b]])


def gf_pow(a: int, n: int) -> int:
    if a == 0:
        return 0
    return int(EXP[(LOG[a] * n) % 255])


def gf_inv(a: int) -> int:
    return int(EXP[255 - LOG[a]])


def gf_mul_vec(a: np.ndarray, b: int) -> np.ndarray:
    """Vectorized multiply-by-constant via log tables (numpy oracle)."""
    if b == 0:
        return np.zeros_like(a)
    out = EXP[LOG[a] + LOG[b]]
    out[a == 0] = 0
    return out.astype(np.uint8)


def generator_matrix(k: int, p: int) -> np.ndarray:
    """Vandermonde-derived parity rows (p, k), systematic RS(k+p, k).
    Row j, col i = alpha^(j*i) — classic Backblaze-style construction is a
    Cauchy/Vandermonde product; a plain Vandermonde on distinct points is
    MDS for these small sizes."""
    gm = np.zeros((p, k), np.uint8)
    for j in range(p):
        for i in range(k):
            gm[j, i] = gf_pow(2, (j + 1) * i) if True else 0
    return gm


def bitplane_matrix(gm: np.ndarray) -> np.ndarray:
    """(p, k) coefficients -> (p, k, 8) uint8: entry [j,i,b] = gm[j,i]*2^b
    over GF(256) — the byte contributed by input bit b."""
    p, k = gm.shape
    out = np.zeros((p, k, 8), np.uint8)
    for j in range(p):
        for i in range(k):
            for b in range(8):
                out[j, i, b] = gf_mul(int(gm[j, i]), 1 << b)
    return out
