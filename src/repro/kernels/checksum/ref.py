"""Oracle: the stack's own vectorized checksum (itself numpy-validated)."""
from repro.net.bytesops import checksum16


def checksum_ref(payload, length):
    return checksum16(payload, 0, length)
