"""Public op: jit'd batch checksum with kernel/oracle selection."""
import functools

import jax

from repro.kernels.checksum.kernel import checksum_pallas
from repro.kernels.checksum.ref import checksum_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def checksum(payload, length, use_pallas: bool = True):
    if use_pallas:
        return checksum_pallas(payload, length)
    return checksum_ref(payload, length)
