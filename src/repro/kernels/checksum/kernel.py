"""Pallas TPU kernel: RFC 1071 internet checksum over packet batches.

The per-byte hot spot of the protocol tiles (eth/ip/udp parse each touch
every payload byte).  Blocked (Bb, L) uint8 -> per-packet 16-bit ones-
complement sums; length masking in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BB = 8          # packets per block


def _csum_kernel(data_ref, len_ref, out_ref):
    data = data_ref[...].astype(jnp.uint32)        # (BB, L)
    length = len_ref[...].astype(jnp.int32)        # (BB,)
    L = data.shape[1]
    idx = jax.lax.broadcasted_iota(jnp.int32, data.shape, 1)
    data = jnp.where(idx < length[:, None], data, 0)
    words = (data[:, 0::2] << 8) | data[:, 1::2]
    total = words.sum(axis=1)
    total = (total & 0xFFFF) + (total >> 16)
    total = (total & 0xFFFF) + (total >> 16)
    total = (total & 0xFFFF) + (total >> 16)
    out_ref[...] = (~total) & jnp.uint32(0xFFFF)


def checksum_pallas(payload, length, *, interpret: bool = True):
    """payload: (B, L) uint8 (L even), length: (B,) int32 -> (B,) uint32."""
    B, L = payload.shape
    assert L % 2 == 0
    pad = (-B) % BB
    if pad:
        payload = jnp.pad(payload, ((0, pad), (0, 0)))
        length = jnp.pad(length, ((0, pad),))
    Bp = payload.shape[0]
    out = pl.pallas_call(
        _csum_kernel,
        grid=(Bp // BB,),
        in_specs=[pl.BlockSpec((BB, L), lambda b: (b, 0)),
                  pl.BlockSpec((BB,), lambda b: (b,))],
        out_specs=pl.BlockSpec((BB,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.uint32),
        interpret=interpret,
    )(payload, length)
    return out[:B]
