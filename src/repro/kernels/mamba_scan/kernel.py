"""Pallas TPU kernel: fused Mamba-1 selective scan.

The XLA fallback (associative scan) materializes O(S * D * N * log chunk)
fp32 intermediates in HBM — the dominant memory-roofline term for
falcon-mamba (EXPERIMENTS.md §Roofline).  This kernel fuses the recurrence:
inputs u, dt (B, S, D), Bm, Cm (B, S, N), A (D, N); the state h (bd, N)
lives in VMEM scratch across sequence blocks, and only u/dt/Bm/Cm/y ever
touch HBM — O(S * D) traffic, an ~N*log(Q) ≈ 2 orders-of-magnitude cut.

Grid: (B, nD, nS) — sequence innermost (sequential), h persists across it.
Inside a block the timestep loop is a lax.fori over bs steps of (bd, N)
vector ops (VPU work, no MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mamba_kernel(u_ref, dt_ref, bm_ref, cm_ref, a_ref, y_ref, h_scr, *,
                  bs: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    u = u_ref[0].astype(jnp.float32)       # (bs, bd)
    dt = dt_ref[0].astype(jnp.float32)     # (bs, bd)
    bm = bm_ref[0].astype(jnp.float32)     # (bs, N)
    cm = cm_ref[0].astype(jnp.float32)     # (bs, N)
    A = a_ref[...].astype(jnp.float32)     # (bd, N)

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * A)                 # (bd, N)
        h = decay * h + (dt[t] * u[t])[:, None] * bm[t][None, :]
        y_t = jnp.sum(h * cm[t][None, :], axis=1)           # (bd,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    ys0 = jnp.zeros((bs,) + h_scr.shape[:1], jnp.float32)
    h, ys = jax.lax.fori_loop(0, bs, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def mamba_scan_pallas(u, dt, bm, cm, A, *, bd: int = 512, bs: int = 256,
                      interpret: bool = True):
    """u, dt: (B, S, D); bm, cm: (B, S, N); A: (D, N) -> y (B, S, D)
    where y[b,t,d] = sum_n C[b,t,n] * h[b,t,d,n] (the D*u skip and gating
    stay in the caller)."""
    B, S, D = u.shape
    N = bm.shape[-1]
    bd = min(bd, D)
    bs = min(bs, S)
    assert D % bd == 0 and S % bs == 0
    grid = (B, D // bd, S // bs)
    kernel = functools.partial(_mamba_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, bs, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, bs, N), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((bd, N), lambda b, d, s: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, bd), lambda b, d, s: (b, s, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, bm, cm, A)
