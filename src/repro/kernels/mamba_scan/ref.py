"""Pure-jnp oracle: the model's own chunked associative-scan recurrence."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.blocks import linear_recurrence


def mamba_scan_ref(u, dt, bm, cm, A):
    """Same contract as the kernel: y[b,t,d] = sum_n C h."""
    u32 = u.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * A)                       # (B,S,D,N)
    inp = (dt32 * u32)[..., None] * bm[:, :, None, :].astype(jnp.float32)
    B, S, D = u.shape
    h0 = jnp.zeros((B, D, A.shape[1]), jnp.float32)
    hs, _ = linear_recurrence(decay, inp, h0)
    return jnp.einsum("bsdn,bsn->bsd", hs, cm.astype(jnp.float32))
