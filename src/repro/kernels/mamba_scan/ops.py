"""Public op: fused selective scan with kernel/oracle selection."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def mamba_scan(u, dt, bm, cm, A, use_pallas: bool = True):
    if use_pallas:
        return mamba_scan_pallas(u, dt, bm, cm, A)
    return mamba_scan_ref(u, dt, bm, cm, A)
