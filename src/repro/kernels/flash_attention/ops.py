"""Public op: flash attention in model layout (B, S, KV, G, hd)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "use_pallas", "bq", "bk"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    use_pallas: bool = True, bq: int = 256, bk: int = 256):
    """q: (B, S, KV, G, hd); k, v: (B, S, KV, hd) -> (B, S, KV, G, hd)."""
    B, S, KV, G, hd = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * KV * G, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    fn = flash_attention_pallas if use_pallas else attention_ref
    of = fn(qf, kf, vf, causal=causal, window=window) if not use_pallas else \
        flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                               bq=bq, bk=bk)
    return of.reshape(B, KV, G, S, hd).transpose(0, 3, 1, 2, 4)
