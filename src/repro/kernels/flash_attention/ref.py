"""Pure-jnp oracle: dense masked softmax attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (BH, S, hd); k, v: (BKV, S, hd). Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bsh,bth->bst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bst,bth->bsh", w, vv.astype(jnp.float32)).astype(q.dtype)
