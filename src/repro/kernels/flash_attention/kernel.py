"""Pallas TPU kernel: flash attention (prefill), GQA + sliding window.

Grid: (BH, nQ, nK) with the KV dimension innermost (sequential on TPU);
online-softmax running max / denominator / accumulator live in VMEM
scratch across KV iterations and are flushed to the output on the last KV
block.  Causal + window masking prunes by block before it prunes by
element.  Block sizes are 128-aligned for the MXU.

q is laid out (B*H, S, hd); k/v are (B*KV, S, hd) — the index map folds
the GQA group so each q head reads its kv head's blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, d_scr, acc_scr, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        d_scr[...] = jnp.zeros_like(d_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)               # (bq, hd)
    k = k_ref[0].astype(jnp.float32)               # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    d_scr[...] = d_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        d = jnp.maximum(d_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / d[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = True):
    """q: (BH, S, hd); k, v: (BKV, S, hd) with BH = BKV * G.
    Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    n_q, n_k = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
