"""Version-compat shims for the jax mesh/sharding API.

The repo targets two generations of jax:

  * newer jax: ``jax.make_mesh(..., axis_types=(jax.sharding.AxisType.Auto,
    ...))`` and the ``jax.set_mesh(mesh)`` context manager;
  * jax <= 0.4.x: ``jax.make_mesh`` has no ``axis_types`` kwarg,
    ``jax.sharding.AxisType`` does not exist, and the context-mesh is
    entered via the ``Mesh`` object itself.

Everything that builds a mesh (launch/mesh.py, the multi-device test
subprocess, elastic-restore tests) goes through these two helpers so the
suite stays green on either version.
"""
from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str]):
    """jax.make_mesh with Auto axis_types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names))


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh  # jax.sharding.Mesh is itself a context manager on 0.4.x


def get_context_mesh():
    """The ambient (context) mesh, or None when none is installed."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax._src import mesh as mesh_lib  # jax 0.4.x: Mesh ctx manager
    resources = getattr(mesh_lib, "thread_resources", None)
    if resources is None:
        return None
    physical = resources.env.physical_mesh
    return None if physical.empty else physical


def shard_map(body, *, mesh, in_specs, out_specs):
    """jax.shard_map with replication checking off, on either API."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
