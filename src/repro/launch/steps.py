"""Step functions (train / prefill / decode) and ShapeDtypeStruct input specs
for the dry-run and the real trainer/server.

``input_specs(arch, shape_name, mesh, multi_pod)`` returns a kwargs dict of
sharding-annotated ShapeDtypeStructs — weak-type-correct, shardable, no
device allocation — exactly what ``jax.jit(step).lower(**specs)`` needs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import Policy, make_policy, logical_to_spec


# ---------------------------------------------------------------------------
# step functions


def default_microbatches(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """Gradient-accumulation factor: keep per-microbatch activation volume
    bounded.  Grad accumulation happens in the grads' own dtype, sharded
    like params, so the extra state is one param-sized buffer."""
    if shape.kind != "train":
        return 1
    tokens = shape.batch * shape.seq
    # per-microbatch token targets by model width (activation ceiling)
    target = (65536 if cfg.d_model >= 5000 else
              131072 if cfg.d_model >= 3000 else 262144)
    mb = max(1, tokens // target)
    while shape.batch % mb:
        mb -= 1
    return mb


def make_train_step(cfg: ModelConfig, policy: Policy,
                    opt_cfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    microbatches: int = 1):
    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: model.loss_fn(cfg, p, batch, policy))(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def mb_step(gsum, mbatch):
                loss, g = grads_of(params, mbatch)
                # barrier: keep the accumulation add OUT of the layer loop
                # (XLA otherwise sinks it, re-reading the full stacked grad
                # buffers once per layer iteration)
                g = jax.lax.optimization_barrier(g)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return gsum, loss

            gzero = jax.tree.map(jnp.zeros_like, params)
            gsum, losses = jax.lax.scan(mb_step, gzero, split)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
        new_params, new_opt, metrics = adamw.update(grads, opt_state, params,
                                                    opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics
    return train_step


def make_prefill_step(cfg: ModelConfig, policy: Policy):
    def prefill_step(params, batch):
        logits, cache = model.prefill(cfg, params, batch, policy)
        return model.greedy_token(cfg, logits), cache
    return prefill_step


def make_decode_step(cfg: ModelConfig, policy: Policy):
    def decode_step(params, cache, token, pos):
        logits, cache = model.decode_step(cfg, params, cache, token, pos,
                                          policy)
        return model.greedy_token(cfg, logits), cache
    return decode_step


def step_fn_for(cfg: ModelConfig, shape: ShapeSpec, policy: Policy,
                microbatches: int = None):
    if shape.kind == "train":
        mb = microbatches or default_microbatches(cfg, shape)
        return make_train_step(cfg, policy, microbatches=mb)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, policy)
    return make_decode_step(cfg, policy)


# ---------------------------------------------------------------------------
# sharding-annotated ShapeDtypeStruct specs


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_sds(cfg: ModelConfig, mesh: Mesh, policy: Policy):
    shapes = model.param_shapes(cfg)
    specs = model.param_specs(cfg)

    def one(spec, shaped):
        ps = logical_to_spec(spec, policy, shaped.shape)
        return _sds(shaped.shape, shaped.dtype, mesh, ps)
    return jax.tree.map(one, specs, shapes,
                        is_leaf=lambda x: isinstance(x, tuple))


def opt_sds(cfg: ModelConfig, mesh: Mesh, policy: Policy):
    p = param_sds(cfg, mesh, policy)
    return {"m": p, "v": p,
            "step": _sds((), jnp.int32, mesh, P())}


def _batch_axes(policy: Policy, n: int) -> P:
    """Batch-dim sharding only when it divides (long_500k has batch 1)."""
    return policy.dp if n % max(1, policy.dp_size()) == 0 else None


def batch_sds(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, policy: Policy,
              with_labels: bool) -> Dict[str, Any]:
    B, S = shape.batch, shape.seq
    b = _batch_axes(policy, B)
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio_stub":
        out["frames"] = _sds((B, S, cfg.d_model), jnp.float32, mesh,
                             P(b, None, None))
    else:
        out["tokens"] = _sds((B, S), jnp.int32, mesh, P(b, None))
    if cfg.frontend == "vision_stub":
        out["image_embeds"] = _sds((B, cfg.n_image_embeds, cfg.d_model),
                                   jnp.float32, mesh, P(b, None, None))
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32, mesh, P(b, None))
    return out


def cache_sds(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, policy: Policy,
              stacked: bool = False):
    B, S = shape.batch, shape.seq
    shapes = model.cache_shapes(cfg, B, S, stacked=stacked)
    b = _batch_axes(policy, B)

    def spec_for(path, leaf):
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        stacked_leaf = "units" in names     # leading n_units dim
        key = names[-1]
        if key in ("k", "v"):
            seq_ax = 1 + (1 if stacked_leaf else 0)
            if leaf.shape[seq_ax] < S and b is not None:
                # rolling-window cache: batch-only sharding (local shifts)
                core = P(b, None, None, None)
            else:
                core = policy.cache_spec(B, cfg.hd)
        elif key == "h":
            nd = leaf.ndim - (1 if stacked_leaf else 0)
            core = P(b, policy.tp, *([None] * (nd - 2)))
        elif key == "conv":
            core = P(b, None, policy.tp)
        else:  # pragma: no cover
            core = P()
        if stacked_leaf:
            core = P(None, *core)
        return _sds(leaf.shape, leaf.dtype, mesh, core)

    return jax.tree_util.tree_map_with_path(spec_for, shapes)


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                multi_pod: bool = False, cfg: ModelConfig = None,
                policy: Policy = None) -> Dict[str, Any]:
    """kwargs of ShapeDtypeStructs for the step function of this cell."""
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    policy = policy or make_policy(mesh, multi_pod=multi_pod)
    opt = adamw.AdamWConfig(state_dtype=None)

    if shape.kind == "train":
        return {
            "params": param_sds(cfg, mesh, policy),
            "opt_state": opt_sds(cfg, mesh, policy),
            "batch": batch_sds(cfg, shape, mesh, policy, with_labels=True),
        }
    if shape.kind == "prefill":
        return {
            "params": param_sds(cfg, mesh, policy),
            "batch": batch_sds(cfg, shape, mesh, policy, with_labels=False),
        }
    b = _batch_axes(policy, shape.batch)
    return {
        "params": param_sds(cfg, mesh, policy),
        "cache": cache_sds(cfg, shape, mesh, policy),
        "token": _sds((shape.batch,), jnp.int32, mesh, P(b)),
        "pos": _sds((), jnp.int32, mesh, P()),
    }
