"""Static analysis of compiled HLO: collective traffic, roofline terms.

cost_analysis() gives per-device HLO FLOPs and bytes accessed, but NOT
collective bytes — those are recovered by parsing the compiled module text:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op's operand/result sizes are summed with ring-algorithm
link-traffic factors.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
ICI_BW = 45e9            # bytes/s per link (~50 GB/s, derated)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# result may be a tuple: "%x = (f32[..]{..}, f32[..]{..}) all-reduce(" etc.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z0-9\-]+)(?:\.[0-9]+)?\(", re.M)
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^=]*?\}|\[\d+,\d+\]<=\[[0-9,]+\][^ ,)]*)")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(attrs: str) -> Optional[int]:
    m = _GROUPS_RE.search(attrs)
    if not m:
        return None
    g = m.group(1)
    if g.startswith("[{") or g.startswith("{{"):
        first = g[g.index("{", 1) + 1: g.index("}", 1)]
        return max(1, first.count(",") + 1)
    m2 = re.match(r"\[(\d+),(\d+)\]<=", g)
    if m2:
        return int(m2.group(2))
    return None


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    full_bytes: int             # size of the *unsharded* buffer (see parse)
    group_size: Optional[int]
    line: str

    @property
    def link_bytes(self) -> float:
        """Per-chip bytes crossing ICI links (ring-algorithm estimates):
          all-gather / reduce-scatter / all-to-all:  full * (n-1)/n
          all-reduce:                                2 * full * (n-1)/n
          collective-permute:                        full
        """
        n = self.group_size or 2
        f = (n - 1) / n
        if self.kind == "all-reduce":
            return 2 * self.full_bytes * f
        if self.kind == "collective-permute":
            return float(self.full_bytes)
        return self.full_bytes * f


def _tuple_parts(type_str: str) -> List[int]:
    return [shape_bytes(p) for p in type_str.strip("()").split(",") if "[" in p]


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        opname = m.group(3)
        base = next((c for c in _COLLECTIVES
                     if opname == c or opname.startswith(c + "-")), None)
        if base is None or opname.endswith("-done"):
            continue  # async pairs are counted at -start
        tstr = m.group(2)
        n = _group_size(line)
        if tstr.startswith("("):
            full = max(_tuple_parts(tstr) or [0])
        else:
            full = shape_bytes(tstr)
            if base == "reduce-scatter":  # plain form: result is the shard
                full *= (n or 2)
        ops.append(CollectiveOp(kind=base, full_bytes=full, group_size=n,
                                line=line.strip()[:160]))
    return ops


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    ops = parse_collectives(hlo_text)
    by_kind: Dict[str, float] = {}
    for op in ops:
        by_kind[op.kind] = by_kind.get(op.kind, 0.0) + op.link_bytes
    by_kind["total"] = sum(by_kind.values())
    by_kind["count"] = len(ops)
    return by_kind


# ---------------------------------------------------------------------------
# roofline


@dataclasses.dataclass
class Roofline:
    """Per-chip roofline terms, in seconds."""
    flops: float                # per-device HLO flops
    hbm_bytes: float            # per-device bytes accessed
    coll_bytes: float           # per-device ICI link bytes
    model_flops: float          # 6*N*D (or 6*N_active*D) / n_chips

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_fraction(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the *useful* model FLOPs achieve when
        running at the bound: (model_flops / t_bound) / peak."""
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / self.t_bound) / PEAK_FLOPS

    def to_dict(self) -> Dict[str, float]:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "model_flops_per_device": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_fraction": self.useful_flop_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape, n_params_total: int, n_params_active: int):
    """6*N*D for train (fwd+bwd), 2*N*D for inference, per the assignment.
    D = tokens processed by the step; decode steps process `batch` tokens."""
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.batch  # one token per sequence
