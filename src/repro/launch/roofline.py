"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
artifacts.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
Prints a markdown table; --update-experiments rewrites the section in
EXPERIMENTS.md between the ROOFLINE markers.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

COLS = ("arch", "shape", "mesh", "bound", "t_c", "t_m", "t_x", "frac",
        "useful", "fits", "hbm")


def load(directory: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def _variant(rec) -> str:
    parts = rec["cell"].split("__")
    return parts[3] if len(parts) > 3 else "baseline"


def table(recs, mesh_filter: str = None) -> str:
    lines = ["| arch | shape | mesh | variant | bound | t_compute s | "
             "t_memory s | t_collective s | roofline frac | "
             "useful-FLOP frac | fits 16GiB | HBM/dev GiB |",
             "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        ro = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {_variant(r)} "
            f"| **{ro['bottleneck']}** | {ro['t_compute_s']:.4f} "
            f"| {ro['t_memory_s']:.4f} | {ro['t_collective_s']:.4f} "
            f"| {ro['roofline_fraction']:.3f} "
            f"| {ro['useful_flop_fraction']:.3f} "
            f"| {'yes' if r['memory']['fits_16GiB'] else 'NO'} "
            f"| {r['memory']['hbm_per_device']/2**30:.2f} |")
    return "\n".join(lines)


def summary(recs) -> str:
    ok = [r for r in recs if r["mesh"] == "pod16x16"]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_fraction"])[:3]
    collbound = [r for r in ok
                 if r["roofline"]["bottleneck"] == "collective"]
    lines = ["", f"Cells compiled: {len(recs)} "
             f"(single-pod {sum(r['mesh']=='pod16x16' for r in recs)}, "
             f"multi-pod {sum(r['mesh']=='pod2x16x16' for r in recs)})",
             "Worst roofline fractions (hillclimb candidates): "
             + ", ".join(f"{r['cell']} ({r['roofline']['roofline_fraction']:.3f})"
                         for r in worst),
             f"Collective-bound cells: "
             + ", ".join(r["cell"] for r in collbound)]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load(args.dir)
    print(table(recs, args.mesh))
    print(summary(recs))


if __name__ == "__main__":
    main()
