"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips, with the
"pod" axis carrying pure data parallelism across the inter-pod network.
"""
from __future__ import annotations

from repro.launch.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(devices: int, *, model_parallel: int = None):
    """Mesh for an arbitrary device count (elastic scaling / local runs)."""
    mp = model_parallel or min(16, devices)
    assert devices % mp == 0
    return make_mesh((devices // mp, mp), ("data", "model"))
