import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), prove it fits, and extract the
roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, and the parsed collective schedule; the
roofline table (launch/roofline.py, EXPERIMENTS.md) reads these artifacts.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import hlo_analysis as H
from repro.launch import hlo_walk as W
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs, step_fn_for
from repro.models import model
from repro.sharding import make_policy


def active_params(cfg) -> int:
    """Per-token active parameter count (MoE: top_k + shared experts only)."""
    total = model.count_params(cfg)
    if cfg.n_experts == 0:
        return total
    entries = list(cfg.pattern) * cfg.n_units + list(cfg.remainder)
    n_moe_layers = sum(1 for e in entries if "moe" in e)
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, opt_decode: bool = False,
             suffix: str = "", cfg_overrides: dict = None,
             microbatches: int = None) -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}{suffix}"
    if not applicable(cfg, shape):
        rec = {"cell": cell, "status": "skipped",
               "reason": "shape not applicable (DESIGN.md §Arch-applicability)"}
        _write(out_dir, cell, rec)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    # donation: trainer re-uses params/opt buffers, decode re-uses the cache
    donate = (("params", "opt_state") if shape.kind == "train" else
              (("cache",) if shape.kind == "decode" else ()))
    with jax.set_mesh(mesh):
        policy = make_policy(mesh, multi_pod=multi_pod,
                             resident_decode=opt_decode)
        specs = input_specs(arch, shape_name, mesh, multi_pod, cfg=cfg,
                            policy=policy)
        step = step_fn_for(cfg, shape, policy, microbatches=microbatches)
        lowered = jax.jit(step, donate_argnames=donate).lower(**specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
    wr = W.walk(text)  # trip-count-aware flops/bytes/collective analysis
    n_total = model.count_params(cfg)
    n_active = active_params(cfg)
    mf = H.model_flops_for(cfg, shape, n_total, n_active) / n_chips
    roof = H.Roofline(
        flops=wr.flops,
        hbm_bytes=wr.hbm_bytes,
        coll_bytes=wr.coll_link_bytes,
        model_flops=mf,
    )
    hbm_per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                   + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "cell": cell, "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_chips": n_chips,
        "params_total": n_total, "params_active": n_active,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "hbm_per_device": hbm_per_dev,
            "fits_16GiB": bool(hbm_per_dev < 16 * 2**30),
        },
        "cost": {k: float(v) for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": {"by_kind": wr.coll_by_kind, "count": wr.coll_count,
                        "n_while": wr.n_while,
                        "unknown_trip": wr.unknown_trip},
        "top_bytes": [[v, d] for v, d in wr.top_bytes],
        "top_flops": [[v, d] for v, d in wr.top_flops],
        "roofline": roof.to_dict(),
    }
    _write(out_dir, cell, rec)
    if verbose:
        r = rec["roofline"]
        print(f"[{cell}] compile={t_compile:.1f}s "
              f"hbm/dev={hbm_per_dev/2**30:.2f}GiB "
              f"fits={rec['memory']['fits_16GiB']} "
              f"t_c={r['t_compute_s']:.4f} t_m={r['t_memory_s']:.4f} "
              f"t_x={r['t_collective_s']:.4f} bound={r['bottleneck']} "
              f"roofline={r['roofline_fraction']:.3f}")
    return rec


def _write(out_dir, cell, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--opt-decode", action="store_true",
                    help="resident-weight decode (§Perf variant)")
    ap.add_argument("--suffix", default="",
                    help="artifact name suffix, e.g. __opt")
    ap.add_argument("--ssm-dtype", default=None,
                    choices=["float32", "bfloat16"])
    ap.add_argument("--ssm-chunk", type=int, default=None)
    ap.add_argument("--ssm-impl", default=None, choices=["assoc", "noscan"])
    ap.add_argument("--moe-shard-ff", action="store_true")
    ap.add_argument("--attn-impl", default=None, choices=["online", "iso"])
    ap.add_argument("--mb", type=int, default=None,
                    help="microbatch override for train cells")
    args = ap.parse_args()
    overrides = {}
    if args.ssm_dtype:
        overrides["ssm_scan_dtype"] = args.ssm_dtype
    if args.ssm_chunk:
        overrides["ssm_chunk"] = args.ssm_chunk
    if args.ssm_impl:
        overrides["ssm_impl"] = args.ssm_impl
    if args.moe_shard_ff:
        overrides["moe_shard_ff"] = True
    if args.attn_impl:
        overrides["attn_impl"] = args.attn_impl

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            run_cell(arch, shape, args.multi_pod, args.out,
                     opt_decode=args.opt_decode, suffix=args.suffix,
                     cfg_overrides=overrides or None,
                     microbatches=args.mb)
        except Exception:
            failures += 1
            cellname = f"{arch}__{shape}"
            print(f"[{cellname}] FAILED")
            traceback.print_exc()
            _write(args.out, cellname + ("__pod2x16x16" if args.multi_pod
                                         else "__pod16x16"),
                   {"cell": cellname, "status": "failed",
                    "error": traceback.format_exc()[-2000:]})
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
