"""Trip-count-aware HLO cost walker.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, ignoring
trip counts — useless for scanned transformer stacks (verified: an 8-step
scan reports 1/8 the unrolled FLOPs).  This walker parses the compiled HLO
text, builds the computation call graph (fusion ``calls=``, while
``body=/condition=`` with ``known_trip_count``), and accumulates per-device:

  * flops           dot contractions (2*M*N*K), weighted elementwise ops
  * hbm_bytes       operand+result sizes at fusion boundaries (a TPU-style
                    "fusions hit HBM once" traffic proxy)
  * collective bytes  per collective kind, with ring-algorithm link factors

every term multiplied by the product of enclosing while trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OPCODE_RE = re.compile(r"([a-z0-9\-]+)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"(?:true_computation|false_computation|branch_computations=\{[^}]*)=?%?([\w.\-]+)")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^}]*\}[^=]*?\}|\[\d+,\d+\]<=\[[0-9,]+\][^ ,)]*)")

_EW_CHEAP = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
             "abs", "negate", "compare", "select", "and", "or", "xor", "not",
             "floor", "ceil", "round-nearest-afz", "round-nearest-even",
             "clamp", "sign", "shift-left", "shift-right-logical",
             "shift-right-arithmetic", "remainder"}
_EW_EXP = {"exponential", "exponential-minus-one", "log", "log-plus-one",
           "tanh", "logistic", "sqrt", "rsqrt", "cbrt", "power", "cosine",
           "sine", "tan", "atan2", "erf"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# Byte accounting mimics TPU fusion: only ops that *materialize* buffers
# count HBM traffic; elementwise/broadcast/convert chains are assumed fused
# into their consumers (documented in EXPERIMENTS.md §Roofline methodology).
_BYTE_OPS = {"dot", "convolution", "fusion", "reduce", "reduce-window",
             "sort", "copy", "gather", "scatter", "pad", "concatenate",
             "slice", "reverse", "rng", "custom-call", "transpose",
             "cholesky", "triangular-solve", "fft", "select-and-scatter"}


def shape_elems(type_str: str) -> int:
    total = 0
    for _, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n
    return total


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str       # operand list + attributes (everything after open paren)
    is_root: bool = False

    def operand_names(self) -> List[str]:
        # operands come before the first close-paren at depth 0; newer XLA
        # prints operand types inline (`dot(f32[32,64]{1,0} %a, ...)`), so
        # commas inside [] / {} must not split tokens — track all brackets
        depth = 0
        out = []
        cur = []
        for ch in self.rest:
            if ch in "([{":
                depth += 1
                cur.append(ch)
            elif ch == ")" and depth == 0:
                break
            elif ch in ")]}":
                depth -= 1
                cur.append(ch)
            elif ch == "," and depth == 0:
                out.append("".join(cur).strip())
                cur = []
            else:
                cur.append(ch)
        if cur:
            out.append("".join(cur).strip())
        names = []
        for tok in out:
            m = re.search(r"%([\w.\-]+)\s*$", tok)
            names.append(m.group(1) if m else tok)
        return names


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    types: Dict[str, str] = dataclasses.field(default_factory=dict)


def _parse_instr(line: str) -> Optional[Instr]:
    s = _COMMENT_RE.sub("", line).strip()
    is_root = s.startswith("ROOT ")
    if is_root:
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):            # tuple type: balanced-paren scan
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str, rem = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rem = rest[:sp], rest[sp + 1:].lstrip()
    m = _OPCODE_RE.match(rem)
    if not m:
        return None
    return Instr(name, type_str, m.group(1), m.group(2), is_root)


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(_COMMENT_RE.sub("", line))
            if m:
                cur = Computation(m.group(2), bool(m.group(1)))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.instrs.append(ins)
            cur.types[ins.name] = ins.type_str
    return comps, entry


def _group_size(rest: str) -> Optional[int]:
    m = _GROUPS_RE.search(rest)
    if not m:
        return None
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:g.index("}", 2)]
        return max(1, first.count(",") + 1)
    m2 = re.match(r"\[(\d+),(\d+)\]<=", g)
    if m2:
        return int(m2.group(2))
    return None


def _dot_flops(ins: Instr, comp: Computation) -> float:
    res = 1
    for d in _dims(ins.type_str):
        res *= d
    ops = ins.operand_names()
    lhs_t = comp.types.get(ops[0], "") if ops else ""
    lhs_dims = _dims(lhs_t)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    k = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * res * k


@dataclasses.dataclass
class WalkResult:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_link_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: int = 0
    n_while: int = 0
    unknown_trip: int = 0
    top_bytes: List[Tuple[float, str]] = dataclasses.field(default_factory=list)
    top_flops: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    def to_dict(self):
        d = dataclasses.asdict(self)
        return d

    def _note(self, kind, val, desc, keep=16):
        lst = self.top_bytes if kind == "b" else self.top_flops
        lst.append((val, desc))
        lst.sort(key=lambda t: -t[0])
        del lst[keep:]


def _coll_link_bytes(kind: str, ins: Instr, comp: Computation) -> float:
    n = _group_size(ins.rest) or 2
    f = (n - 1) / n
    tstr = ins.type_str
    if tstr.startswith("("):
        parts = [shape_bytes(p) for p in tstr.strip("()").split(",")
                 if "[" in p]
        full = max(parts or [0])
    else:
        full = shape_bytes(tstr)
        if kind == "reduce-scatter":
            full *= n
    if kind == "all-reduce":
        return 2.0 * full * f
    if kind == "collective-permute":
        return float(full)
    return full * f


def walk(text: str) -> WalkResult:
    comps, entry = parse_module(text)
    if entry is None:
        return WalkResult()
    # computations reached via fusion `calls=` contribute no byte traffic of
    # their own (the fusion instruction accounts for it) but DO contribute
    # flops.
    fusion_targets = set()
    for c in comps.values():
        for ins in c.instrs:
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m:
                    fusion_targets.add(m.group(1))

    res = WalkResult()
    # ---- build call-graph edges (comp -> [(callee, factor)]) --------------
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "fusion" or ins.opcode in ("call", "async-start"):
                m = _CALLS_RE.search(ins.rest) or _TO_APPLY_RE.search(ins.rest)
                if m:
                    edges[cname].append((m.group(1), 1.0))
            elif ins.opcode == "while":
                res.n_while += 1
                mt = _TRIP_RE.search(ins.rest)
                trip = float(mt.group(1)) if mt else 1.0
                if not mt:
                    res.unknown_trip += 1
                mb = _BODY_RE.search(ins.rest)
                mc = _COND_RE.search(ins.rest)
                if mb:
                    edges[cname].append((mb.group(1), trip))
                if mc:
                    edges[cname].append((mc.group(1), trip + 1))
            elif ins.opcode == "conditional":
                for m in re.finditer(r"computation[s]?=\{?%?([\w.\-]+)",
                                     ins.rest):
                    edges[cname].append((m.group(1), 1.0))

    # ---- topological multiplicity propagation (HLO call graphs are DAGs) --
    indeg: Dict[str, int] = {c: 0 for c in comps}
    for cname, outs in edges.items():
        for callee, _ in outs:
            if callee in indeg:
                indeg[callee] += 1
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    queue = [c for c, d in indeg.items() if d == 0]
    i = 0
    while i < len(queue):
        cname = queue[i]
        i += 1
        for callee, factor in edges.get(cname, []):
            if callee not in mult:
                continue
            mult[callee] += mult[cname] * factor
            indeg[callee] -= 1
            if indeg[callee] == 0:
                queue.append(callee)

    # second pass: costs (multiplicities now final)
    for cname, comp in comps.items():
        cmult = mult.get(cname, 0.0)
        if cmult <= 0:
            continue
        in_fusion = cname in fusion_targets
        for ins in comp.instrs:
            op = ins.opcode
            # ---- flops
            if op == "dot":
                fl = cmult * _dot_flops(ins, comp)
                res.flops += fl
                if fl > 1e9:
                    res._note("f", fl, f"dot {ins.type_str[:48]} x{cmult:.0f} "
                              f"[{cname[:40]}]")
            elif op == "convolution":
                res.flops += cmult * 2.0 * shape_elems(ins.type_str)
            elif op in _EW_CHEAP:
                res.flops += cmult * shape_elems(ins.type_str)
            elif op in _EW_EXP:
                res.flops += cmult * 4.0 * shape_elems(ins.type_str)
            elif op in ("reduce", "reduce-window"):
                ops_ = ins.operand_names()
                t = comp.types.get(ops_[0], ins.type_str) if ops_ else ins.type_str
                res.flops += cmult * shape_elems(t)
            # ---- collectives
            base = next((c for c in _COLLECTIVES
                         if op == c or op.startswith(c + "-")), None)
            if base is not None and not op.endswith("-done"):
                lb = cmult * _coll_link_bytes(base, ins, comp)
                res.coll_link_bytes += lb
                res.coll_by_kind[base] = res.coll_by_kind.get(base, 0.0) + lb
                res.coll_count += int(cmult)
            # ---- bytes (TPU-fusion traffic proxy)
            if in_fusion:
                continue
            if op == "dynamic-update-slice":
                ops_ = ins.operand_names()
                upd_t = comp.types.get(ops_[1], "") if len(ops_) > 1 else ""
                res.hbm_bytes += cmult * 2.0 * shape_bytes(upd_t)
                continue
            if op == "dynamic-slice":
                res.hbm_bytes += cmult * 2.0 * shape_bytes(ins.type_str)
                continue
            if base is not None:  # collectives: read + write local buffers
                res.hbm_bytes += cmult * 2.0 * shape_bytes(ins.type_str)
                continue
            if op not in _BYTE_OPS:
                continue
            if op == "fusion":
                # a fusion whose root is a dynamic-update-slice writes only
                # the update in place (aliased output); count 2x update size
                m = _CALLS_RE.search(ins.rest)
                callee = comps.get(m.group(1)) if m else None
                root = next((x for x in (callee.instrs if callee else [])
                             if x.is_root), None)
                if root is not None and root.opcode == "dynamic-update-slice":
                    ops_ = root.operand_names()
                    upd_t = (callee.types.get(ops_[1], "")
                             if len(ops_) > 1 else "")
                    res.hbm_bytes += cmult * 2.0 * shape_bytes(upd_t)
                    continue
            opbytes = 0
            for on in ins.operand_names():
                opbytes += shape_bytes(comp.types.get(on, ""))
            b = cmult * (opbytes + shape_bytes(ins.type_str))
            res.hbm_bytes += b
            if b > 2e9:
                res._note("b", b, f"{op} {ins.type_str[:48]} x{cmult:.0f} "
                          f"[{cname[:40]}]")
    return res
