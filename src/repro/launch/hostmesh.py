"""Host-simulated multi-device meshes for tests and benchmarks.

XLA only honours ``--xla_force_host_platform_device_count`` if it is set
*before* the first jax import, so multi-device runs on a CPU box must
happen in a subprocess with a prepared environment.  This module is the
one place that pattern lives (extracted from tests/test_distributed.py):

  * :func:`forced_env` — a subprocess environment forcing N host devices;
  * :func:`run_script` — run a python snippet under that environment,
    with a guard prologue that prints :data:`UNAVAILABLE` and exits 0
    when the forcing did not take (e.g. an accelerator platform already
    claimed the process) so callers can skip instead of fail.

No jax import here: importing this module never touches device state, so
a parent process can use it before (or without) initialising jax.
"""
from __future__ import annotations

import os
import subprocess
import sys
from typing import Optional

FLAG = "--xla_force_host_platform_device_count"
UNAVAILABLE = "HOSTMESH_UNAVAILABLE"

# prologue prepended to every run_script snippet: verify the forced
# device count actually materialised before the caller's code runs
_GUARD = """\
import jax, sys
if jax.device_count() < {n}:
    print("{marker}", jax.device_count())
    sys.exit(0)
"""


def forced_env(devices: int, base_env: Optional[dict] = None) -> dict:
    """A copy of ``base_env`` (default: os.environ) with ``XLA_FLAGS``
    forcing ``devices`` host platform devices (any prior forcing flag is
    replaced) and ``PYTHONPATH`` including ``src``."""
    env = dict(os.environ if base_env is None else base_env)
    flags = [p for p in env.get("XLA_FLAGS", "").split()
             if not p.startswith(FLAG)]
    flags.append(f"{FLAG}={devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    env.setdefault("PYTHONPATH", "src")
    return env


def run_script(script: str, devices: int = 8, timeout: int = 900,
               cwd: Optional[str] = None) -> subprocess.CompletedProcess:
    """Run ``script`` in a subprocess on a forced ``devices``-wide host
    mesh.  The guard prologue exits 0 printing :data:`UNAVAILABLE` when
    the platform refused the forcing — check ``UNAVAILABLE in
    result.stdout`` to skip rather than fail."""
    guarded = _GUARD.format(n=devices, marker=UNAVAILABLE) + script
    return subprocess.run([sys.executable, "-c", guarded],
                          env=forced_env(devices), capture_output=True,
                          text=True, timeout=timeout, cwd=cwd)
