"""LM serving engine: session-slot KV-cache management, batched decode,
and live session migration.

This is the LM-application face of Beehive: each engine instance is an
"application tile" behind the network stack; sessions are flows (the
flow-hash dispatch pins a session to an engine), and `migrate_out` /
`migrate_in` move a session between engines exactly like the paper's TCP
live migration moves a connection — serialize state, reinstall, flip the
NAT/dispatch table.

Cache layout: stacked (n_units leading axis) with a session axis of size
`max_sessions`; per-session positions drive scatter writes in decode.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import ModelConfig
from repro.sharding import SINGLE, Policy


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_sessions: int = 4,
                 max_seq: int = 128, policy: Policy = SINGLE):
        assert cfg.supports_decode
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.M = max_sessions
        self.S = max_seq
        self.cache = model.init_cache(cfg, max_sessions, max_seq)
        self.pos = jnp.zeros((max_sessions,), jnp.int32)
        self.used = np.zeros((max_sessions,), bool)
        self.last_tok = jnp.zeros((max_sessions,), jnp.int32)
        self._decode = jax.jit(self._decode_impl)

    # ---- session lifecycle -----------------------------------------------
    def has_free_slot(self) -> bool:
        return bool((~self.used).any())

    def release(self, sid: int) -> None:
        """Free slot `sid` (explicit close / LRU eviction).  The cache
        column is left in place — a freed slot's stale entries are
        invisible (attention never reads past `pos`, and `pos` is reset on
        the next install)."""
        self.used[sid] = False
        self.pos = self.pos.at[sid].set(0)
        self.last_tok = self.last_tok.at[sid].set(0)

    def new_session(self, prompt_tokens: np.ndarray,
                    extras: Optional[Dict] = None) -> int:
        """Prefill the prompt into a free slot; returns the session id."""
        free = np.where(~self.used)[0]
        if not len(free):
            raise RuntimeError("no free session slots")
        sid = int(free[0])
        batch = {"tokens": jnp.asarray(prompt_tokens)[None, :]}
        if extras:
            batch.update({k: jnp.asarray(v)[None] for k, v in extras.items()})
        logits, pcache = model.prefill(self.cfg, self.params, batch,
                                       self.policy)
        tok = model.greedy_token(self.cfg, logits)[0]
        P = prompt_tokens.shape[0]
        self._install_cache(sid, pcache, P)
        self.pos = self.pos.at[sid].set(P)
        self.last_tok = self.last_tok.at[sid].set(tok)
        self.used[sid] = True
        return sid

    def _install_cache(self, sid: int, pcache, prompt_len: int):
        """Copy a prefill cache (seq length P) into slot `sid`.

        Alignment: global-attention caches are prefix-aligned (position i at
        index i -> pad right); rolling-window caches keep the newest entry
        last (-> pad left). Recurrent states are O(1)."""
        def put(slot_leaf, new_leaf, left: bool):
            new = jnp.moveaxis(new_leaf, 1, 0)[0]       # (U, T, ...)
            T = new.shape[1]
            gap = slot_leaf.shape[2] - T
            pad = [(0, 0)] * new.ndim
            pad[1] = (gap, 0) if left else (0, gap)
            return slot_leaf.at[:, sid].set(jnp.pad(new, pad))

        def merge(path, slot_leaf, new_leaf):
            names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            if names[-1] in ("k", "v"):
                i = int(names[0][1:])                   # pattern position
                mixer, _ = self.cfg.entry(self.cfg.pattern[i])
                return put(slot_leaf, new_leaf, left=(mixer == "attn_l"))
            # recurrent states: (U, 1, ...) -> slot (U, M, ...)
            return slot_leaf.at[:, sid].set(jnp.moveaxis(new_leaf, 1, 0)[0])

        self.cache["units"] = jax.tree_util.tree_map_with_path(
            merge, self.cache["units"], pcache["units"])

        for j in range(len(self.cache["rem"])):
            mixer, _ = self.cfg.entry(self.cfg.remainder[j])

            def merge_rem(path, slot_leaf, new_leaf, _mx=mixer):
                names = [getattr(k, "key", getattr(k, "name", ""))
                         for k in path]
                new = new_leaf[0]                   # drop batch dim
                if names[-1] in ("k", "v"):         # (T, KV, hd)
                    gap = slot_leaf.shape[1] - new.shape[0]
                    pad = [(gap, 0) if _mx == "attn_l" else (0, gap)] + \
                        [(0, 0)] * (new.ndim - 1)
                    new = jnp.pad(new, pad)
                return slot_leaf.at[sid].set(new)

            self.cache["rem"][j] = jax.tree_util.tree_map_with_path(
                merge_rem, self.cache["rem"][j], pcache["rem"][j])

    # ---- batched decode ---------------------------------------------------
    def _decode_impl(self, params, cache, tok, pos):
        logits, cache = model.decode_step(self.cfg, params, cache, tok, pos,
                                          self.policy)
        nxt = model.greedy_token(self.cfg, logits)
        return nxt, cache

    def step(self) -> np.ndarray:
        """One decode step for every active session. Returns next tokens."""
        nxt, self.cache = self._decode(self.params, self.cache,
                                       self.last_tok, self.pos)
        self.pos = self.pos + jnp.asarray(self.used, jnp.int32)
        self.last_tok = jnp.where(jnp.asarray(self.used), nxt, self.last_tok)
        return np.asarray(self.last_tok)

    def generate(self, sid: int, n: int) -> List[int]:
        out = []
        for _ in range(n):
            toks = self.step()
            out.append(int(toks[sid]))
        return out

    # ---- live migration (the paper's §6.7, generalized to sessions) -------
    def migrate_out(self, sid: int) -> Dict:
        """Serialize session `sid` (cache column + position + last token)."""
        blob = {
            "units": jax.tree.map(lambda x: x[:, sid], self.cache["units"]),
            "rem": [jax.tree.map(lambda x: x[sid], c)
                    for c in self.cache["rem"]],
            "pos": self.pos[sid],
            "last_tok": self.last_tok[sid],
        }
        self.used[sid] = False
        return blob

    def migrate_in(self, blob: Dict) -> int:
        free = np.where(~self.used)[0]
        if not len(free):
            raise RuntimeError("no free session slots")
        sid = int(free[0])
        self.cache["units"] = jax.tree.map(
            lambda slot, b: slot.at[:, sid].set(b),
            self.cache["units"], blob["units"])
        for j, b in enumerate(blob["rem"]):
            self.cache["rem"][j] = jax.tree.map(
                lambda s, x: s.at[sid].set(x), self.cache["rem"][j], b)
        self.pos = self.pos.at[sid].set(blob["pos"])
        self.last_tok = self.last_tok.at[sid].set(blob["last_tok"])
        self.used[sid] = True
        return sid
