"""Deterministic, seedable link emulator.

One :class:`Link` is one direction of a path.  Time is an integer tick
(the same clock the TCP engine's RTO runs on).  Every impairment draws
from one seeded ``numpy`` generator in send order, so a (seed, schedule)
pair replays bit-identically — the property tests rely on this.

Model, applied per frame at ``send``:

  1. loss — i.i.d. with probability ``loss``, and/or a two-state
     Gilbert–Elliott chain (:class:`GilbertElliott`) for burst loss; the
     effective drop probability is the larger of the two.
  2. shaping — with ``rate`` (bytes/tick) set, frames serialize one after
     another; bytes waiting to depart form the queue.  A frame that would
     overflow ``queue_bytes`` is tail-dropped; a frame enqueued while the
     queue is at or above ``ecn_threshold`` gets its IP ECN field set to
     CE (checksum re-fixed) — the DCTCP-style marking signal.
  3. delay — fixed one-way ``delay`` plus uniform ``jitter``; with
     probability ``reorder`` a frame is additionally held ``reorder_extra``
     ticks (the classic netem reordering knob).

``deliver(now)`` returns every frame whose arrival tick has passed, in
(arrival, send-order) order.
"""
from __future__ import annotations

import dataclasses
import heapq
import struct
from typing import List, Optional

import numpy as np

from repro.net.bytesops import np_checksum16
from repro.net.frames import l2_offset


@dataclasses.dataclass
class GilbertElliott:
    """Two-state burst-loss chain: good <-> bad with the given transition
    probabilities and per-state loss rates."""
    p_good_bad: float = 0.01
    p_bad_good: float = 0.3
    loss_good: float = 0.0
    loss_bad: float = 1.0


@dataclasses.dataclass
class LinkConfig:
    delay: int = 1                   # one-way delay, ticks
    jitter: int = 0                  # + uniform[0, jitter] ticks
    loss: float = 0.0                # i.i.d. drop probability
    gilbert: Optional[GilbertElliott] = None
    reorder: float = 0.0             # P(frame held reorder_extra ticks)
    reorder_extra: int = 3
    rate: Optional[int] = None       # bytes/tick; None = unshaped
    queue_bytes: int = 1 << 16       # shaping queue bound (tail drop)
    ecn_threshold: Optional[int] = None   # queue bytes; CE-mark above
    seed: int = 0


def _ce_mark(frame: bytes) -> bytes:
    """Set the IP ECN field to CE (11) and re-fix the header checksum.
    Handles Ethernet- and IP-level frames (`frames.l2_offset`)."""
    off = l2_offset(frame)
    b = bytearray(frame)
    b[off + 1] |= 0x03
    b[off + 10:off + 12] = b"\x00\x00"
    csum = np_checksum16(bytes(b[off:off + 20]))
    struct.pack_into("!H", b, off + 10, csum)
    return bytes(b)


class Link:
    """One direction of an emulated path.  See module docstring."""

    def __init__(self, cfg: LinkConfig):
        if cfg.ecn_threshold is not None and cfg.rate is None:
            raise ValueError("ecn_threshold needs rate shaping (the mark "
                             "signal is queue occupancy)")
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self._heap: List = []          # (arrival_tick, send_order, frame)
        self._seq = 0
        self._busy_until = 0           # shaping: tick the wire frees up
        self._queue: List = []         # (depart_tick, nbytes)
        self._bad = False              # Gilbert–Elliott state
        self.stats = {"sent": 0, "delivered": 0, "dropped_loss": 0,
                      "dropped_queue": 0, "marked": 0}

    # ---- internals -------------------------------------------------------
    def _queued_bytes(self, now: int) -> int:
        self._queue = [(t, n) for (t, n) in self._queue if t > now]
        return sum(n for _, n in self._queue)

    def _drop(self) -> bool:
        cfg = self.cfg
        p = cfg.loss
        if cfg.gilbert is not None:
            g = cfg.gilbert
            flip = self.rng.random()
            if self._bad:
                self._bad = flip >= g.p_bad_good
            else:
                self._bad = flip < g.p_good_bad
            p = max(p, g.loss_bad if self._bad else g.loss_good)
        return p > 0 and self.rng.random() < p

    # ---- interface -------------------------------------------------------
    def send(self, frame: bytes, now: int) -> None:
        cfg = self.cfg
        self.stats["sent"] += 1
        if self._drop():
            self.stats["dropped_loss"] += 1
            return
        depart = now
        if cfg.rate is not None:
            depth = self._queued_bytes(now)
            if depth + len(frame) > cfg.queue_bytes:
                self.stats["dropped_queue"] += 1
                return
            if cfg.ecn_threshold is not None and depth >= cfg.ecn_threshold:
                frame = _ce_mark(frame)
                self.stats["marked"] += 1
            tx = max(1, -(-len(frame) // cfg.rate))     # ceil serialization
            depart = max(now, self._busy_until) + tx
            self._busy_until = depart
            self._queue.append((depart, len(frame)))
        arrival = depart + cfg.delay
        if cfg.jitter:
            arrival += int(self.rng.integers(0, cfg.jitter + 1))
        if cfg.reorder and self.rng.random() < cfg.reorder:
            arrival += cfg.reorder_extra
        heapq.heappush(self._heap, (arrival, self._seq, frame))
        self._seq += 1

    def deliver(self, now: int) -> List[bytes]:
        out = []
        while self._heap and self._heap[0][0] <= now:
            out.append(heapq.heappop(self._heap)[2])
        self.stats["delivered"] += len(out)
        return out

    def pending(self) -> int:
        return len(self._heap)
