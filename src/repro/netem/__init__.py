"""Deterministic network-emulation harness.

The stack has never been exercised under loss, delay, or reordering — the
exact conditions a direct-attached accelerator faces on a datacenter
fabric.  This package is the missing test substrate:

  * :mod:`repro.netem.link` — a seedable, deterministic link emulator:
    one-way delay + jitter, i.i.d. and Gilbert–Elliott burst loss,
    reordering, token-based bandwidth shaping with a bounded queue and an
    ECN CE-marking threshold.  Frames in, frames out, fully host-side
    (numpy) — it composes between any two compiled stacks, or between a
    stack and the Linux-client frame fixtures the tests already use.
  * :mod:`repro.netem.host` — a scripted wire-format TCP client (the
    "unmodified Linux client" of the interop tests, §4.4): active open,
    cumulative ACKs, ECE echo of CE marks.
  * :mod:`repro.netem.harness` — couples a compiled ``TcpStack`` to the
    client through two links and runs tick-driven transfers, reporting
    goodput / recovery-gap / stall statistics (``bench_tcp_loss``).
"""
from repro.netem.harness import StackEndpoint, TransferStats, run_transfer
from repro.netem.host import LinuxTcpClient
from repro.netem.link import GilbertElliott, Link, LinkConfig

__all__ = ["GilbertElliott", "Link", "LinkConfig", "LinuxTcpClient",
           "StackEndpoint", "TransferStats", "run_transfer"]
