"""Scripted wire-format TCP client — the "unmodified Linux client" end of
the emulated path.

All struct/bytes, no JAX: it speaks to the stack exactly like the golden-
frame fixtures in the tests, but *statefully*, so it can drive the full
handshake + lossy-transfer dynamics: active open, cumulative ACKs (with
dup-ACKs for out-of-order arrivals, which is what arms the server's fast
retransmit), tail-overlap acceptance for go-back-N retransmissions, and
ECE echo when a delivered segment carries an IP CE mark.
"""
from __future__ import annotations

import struct
from typing import List, Optional

from repro.net import frames as F
from repro.net import tcp

M32 = 0xFFFFFFFF


def _delta(a: int, b: int) -> int:
    """Signed sequence-space a - b (wrap-safe)."""
    return ((a - b + (1 << 31)) & M32) - (1 << 31)


def parse_tcp_frame(frame: bytes):
    """Parse an Ethernet- or IP-level TCP frame into a field dict."""
    off = F.l2_offset(frame)
    ihl = (frame[off] & 0xF) * 4
    ecn = frame[off + 1] & 0x3
    total = struct.unpack_from("!H", frame, off + 2)[0]
    proto = frame[off + 9]
    src_ip, dst_ip = struct.unpack_from("!II", frame, off + 12)
    t = off + ihl
    sport, dport = struct.unpack_from("!HH", frame, t)
    seq, ack = struct.unpack_from("!II", frame, t + 4)
    doff = (frame[t + 12] >> 4) * 4
    flags = frame[t + 13]
    wnd = struct.unpack_from("!H", frame, t + 14)[0]
    payload = frame[off + ihl + doff:off + total]
    return {"proto": proto, "src_ip": src_ip, "dst_ip": dst_ip,
            "src_port": sport, "dst_port": dport, "seq": seq, "ack": ack,
            "flags": flags, "wnd": wnd, "payload": payload, "ecn": ecn}


class LinuxTcpClient:
    """Receiver-side peer for one connection to the accelerator stack."""

    def __init__(self, client_ip: int, server_ip: int, sport: int = 4000,
                 dport: int = 80, iss: int = 5000, window: int = 65535):
        self.client_ip, self.server_ip = client_ip, server_ip
        self.sport, self.dport = sport, dport
        self.iss = iss
        self.snd_nxt = (iss + 1) & M32
        self.rcv_nxt: Optional[int] = None
        self.established = False
        self.window = window
        self.received = bytearray()
        self.ooo = {}                        # seq -> payload (OOO buffer)
        self.dup_acks_sent = 0
        self.advance_ticks: List[int] = []   # tick of every rcv_nxt advance

    # ---- frame builders --------------------------------------------------
    def _frame(self, flags: int, payload: bytes = b"") -> bytes:
        return F.tcp_eth_frame(self.client_ip, self.server_ip, self.sport,
                               self.dport, seq=self.snd_nxt,
                               ack=self.rcv_nxt or 0, flags=flags,
                               payload=payload, window=self.window)

    def syn_frame(self) -> bytes:
        """Active open (the engine is passive-open only, §4.4)."""
        return F.tcp_eth_frame(self.client_ip, self.server_ip, self.sport,
                               self.dport, seq=self.iss, ack=0,
                               flags=tcp.SYN, window=self.window)

    def keepalive(self, now: int, every: int = 16) -> List[bytes]:
        """Handshake retransmission (a real client's SYN / ACK timers):
        re-send the SYN until the SYN-ACK arrives, and re-send the final
        handshake ACK until the first data segment proves the server left
        SYN_RCVD — either frame can be lost on the emulated path."""
        if now == 0 or now % every:
            return []
        if not self.established:
            return [self.syn_frame()]
        if not self.received:
            return [self._frame(tcp.ACK)]
        return []

    # ---- RX --------------------------------------------------------------
    def on_frame(self, frame: bytes, now: int) -> List[bytes]:
        """Process one server frame; returns the ACKs to send back."""
        f = parse_tcp_frame(frame)
        if f["proto"] != 6 or f["dst_port"] != self.sport:
            return []
        if (f["flags"] & tcp.SYN) and (f["flags"] & tcp.ACK):
            if self.established:
                # late duplicate SYN-ACK (delayed/reordered copy): just
                # re-ack — rewinding rcv_nxt would wedge the transfer
                return [self._frame(tcp.ACK)]
            self.rcv_nxt = (f["seq"] + 1) & M32
            self.established = True
            return [self._frame(tcp.ACK)]
        if not self.established:
            return []
        data = f["payload"]
        ece = tcp.ECE if f["ecn"] == 3 else 0
        if not data:
            return []                        # pure ACK from the server
        off = _delta(self.rcv_nxt, f["seq"])
        if 0 <= off < len(data):
            # in-order (off == 0) or go-back-N tail overlap (off > 0)
            self.received.extend(data[off:])
            self.rcv_nxt = (self.rcv_nxt + len(data) - off) & M32
            # drain any buffered out-of-order data this made contiguous
            # (a Linux receiver buffers OOO segments; only the paper's
            # server engine drops them)
            while self.rcv_nxt in self.ooo:
                seg = self.ooo.pop(self.rcv_nxt)
                self.received.extend(seg)
                self.rcv_nxt = (self.rcv_nxt + len(seg)) & M32
            self.advance_ticks.append(now)
        elif off < 0:
            # hole: buffer the future segment, dup ACK at rcv_nxt
            self.ooo.setdefault(f["seq"], data)
            self.dup_acks_sent += 1
        # cumulative ACK either way (duplicate when nothing advanced)
        return [self._frame(tcp.ACK | ece)]
