"""Tick-driven transfer harness: a compiled TcpStack driving data to the
scripted Linux client through two emulated links.

The server side is the real compiled pipeline (``TcpStack.rx`` for
inbound frames, ``TcpStack.tx_frame`` for every outbound segment, the
engine's ``tick`` for the retransmit clock), so everything the stack does
under loss — dup-ACK fast retransmit, RTO go-back-N, congestion-window
gating, ECE reaction — is exercised through the same code the tests and
benchmarks compile.  All JAX entry points are jitted once per harness
with fixed shapes; the tick loop is plain Python, mirroring the paper's
cycle-driven testbench.

One tick is the unit of everything: link delay/jitter, serialization
time under shaping, and the TCP engine's RTO all count the same clock.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.net import frames as F, tcp
from repro.netem.host import LinuxTcpClient
from repro.netem.link import Link

_META_FIELDS = ("src_ip", "dst_ip", "src_port", "dst_port", "tcp_seq",
                "tcp_ack", "tcp_flags", "tcp_wnd")


class StackEndpoint:
    """Wraps one ``TcpStack`` (server / sender side) for the tick loop.

    Inbound bursts larger than one batch are packed into a preallocated
    :class:`repro.net.frames.FrameArena` and pushed device-resident
    through the stack's streamed RX (`TcpStack.run_stream`, donated state
    carry): one dispatch per ``stream_batches`` batches instead of a
    Python loop dispatching per batch.  ``stream=False`` forces the
    per-batch path (the benchmark baseline)."""

    def __init__(self, stack, conn: int = 0, mss: int = 512,
                 batch: int = 4, rx_width: int = 128, burst: int = 4,
                 stream: bool = True, stream_batches: int = 2):
        self.stack = stack
        self.conn = conn
        self.mss = mss
        self.batch = batch
        self.rx_width = rx_width
        self.burst = burst
        self.stream = stream
        self.arena = F.FrameArena(stream_batches, batch, rx_width)
        self.state = stack.init_state()
        self._rx = jax.jit(lambda st, p, l: stack.rx(st, p, l))
        self._rx_stream = stack.stream_fn()
        self._tx_frame = jax.jit(
            lambda st, m, d, dl: stack.tx_frame(st, m, d, dl))
        self._tick = jax.jit(lambda c: tcp.tick(c))

        def _padded(c, mode):
            # 64B of tail headroom: the TX build chain prepends headers by
            # shifting within a fixed width
            c, seg, data, dlen = tcp.tx_emit(c, conn, mss=mss,
                                             retransmit=mode)
            return c, seg, jnp.pad(data, (0, 64)), dlen

        self._emit = jax.jit(lambda c: _padded(c, False))
        self._emit_fast = jax.jit(lambda c: _padded(c, "fast"))
        self._ack_pad = jnp.zeros((64,), jnp.uint8)
        self.frames_tx = 0

    def reset(self):
        self.state = self.stack.init_state()
        self.frames_tx = 0

    # ---- app side --------------------------------------------------------
    def send_payload(self, payload: bytes):
        """Stage the whole transfer in the connection's tx buffer."""
        conn = self.state["conn"]
        assert len(payload) <= int(tcp.app_tx_space(conn, self.conn)), \
            "payload exceeds tx buffer: raise tcp_tx_buf in stack options"
        arr = jnp.asarray(np.frombuffer(payload, np.uint8))
        conn, ok = tcp.app_send(conn, self.conn, arr, len(payload))
        assert bool(ok)
        self.state["conn"] = conn

    # ---- wire side -------------------------------------------------------
    def _build(self, seg_meta, data, dlen) -> bytes:
        q, ql = self._tx_frame(self.state, seg_meta, data, dlen)
        self.frames_tx += 1
        return bytes(np.asarray(q)[0, :int(np.asarray(ql)[0])].tobytes())

    def push(self, frames: List[bytes], now: int) -> List[bytes]:
        """Feed inbound frames through the compiled RX pipeline; returns
        the stack's reply frames (SYN-ACKs / ACKs / fast retransmits).

        Bursts that fit one batch take the per-batch dispatch; larger
        bursts stream arena chunks device-resident (the RX queue is fully
        serviced before any reply TX — RX-priority scheduling)."""
        out: List[bytes] = []
        i = 0
        while i < len(frames):
            if not self.stream or len(frames) - i <= self.batch:
                chunk = frames[i:i + self.batch]
                p = np.zeros((self.batch, self.rx_width), np.uint8)
                l = np.zeros((self.batch,), np.int32)
                for k, f in enumerate(chunk):
                    p[k, :len(f)] = np.frombuffer(f, np.uint8)
                    l[k] = len(f)
                self.state, resps = self._rx(self.state, jnp.asarray(p),
                                             jnp.asarray(l))
            else:
                chunk = frames[i:i + self.arena.capacity]
                self.arena.fill(chunk)
                self.state, outs = self._rx_stream(
                    self.state, jnp.asarray(self.arena.payload),
                    jnp.asarray(self.arena.length))
                resps = {k: v.reshape((-1,) + v.shape[2:])
                         for k, v in outs["tcp_resps"].items()}
            self._emit_replies(resps, len(chunk), out)
            i += len(chunk)
        return out

    def _emit_replies(self, resps, n: int, out: List[bytes]):
        emit = np.asarray(resps["emit"])
        fast = np.asarray(resps["fast_retx"])
        for r in range(n):
            if emit[r]:
                meta = {k: resps[k][r] for k in _META_FIELDS}
                out.append(self._build(meta, self._ack_pad,
                                       jnp.zeros((), jnp.int32)))
            if fast[r]:
                conn, seg, data, dlen = self._emit_fast(
                    self.state["conn"])
                self.state["conn"] = conn
                if bool(seg["emit"]):
                    meta = {k: seg[k] for k in _META_FIELDS}
                    out.append(self._build(meta, data, dlen))

    def poll(self, now: int) -> List[bytes]:
        """One engine tick: retransmit timer, then emit new segments up to
        `burst` (window permitting)."""
        out: List[bytes] = []
        conn, _expired = self._tick(self.state["conn"])
        self.state["conn"] = conn
        for _ in range(self.burst):
            conn, seg, data, dlen = self._emit(self.state["conn"])
            self.state["conn"] = conn
            if not bool(seg["emit"]):
                break
            meta = {k: seg[k] for k in _META_FIELDS}
            out.append(self._build(meta, data, dlen))
        return out

    # ---- progress --------------------------------------------------------
    def fully_acked(self) -> bool:
        c = self.state["conn"]
        return int(c["snd_una"][self.conn]) == int(c["snd_nxt"][self.conn])

    def snd_nxt(self) -> int:
        return int(self.state["conn"]["snd_nxt"][self.conn])


@dataclasses.dataclass
class TransferStats:
    complete: bool
    ticks: int
    delivered: int
    goodput: float              # payload bytes per tick
    p99_gap: float              # p99 inter-advance gap at the client
    max_gap: int
    frames_tx: int
    dup_acks: int
    link_stats: dict


def run_transfer(server: StackEndpoint, client: LinuxTcpClient,
                 link_c2s: Link, link_s2c: Link, payload: bytes,
                 max_ticks: int = 2000) -> TransferStats:
    """Drive one server->client transfer to completion (or the tick
    budget).  Complete means every payload byte was delivered in order at
    the client AND every sequence number was acknowledged back
    (``snd_una == snd_nxt`` — no permanent stall anywhere)."""
    server.send_payload(payload)
    link_c2s.send(client.syn_frame(), 0)
    end = max_ticks
    for t in range(1, max_ticks + 1):
        for f in client.keepalive(t):
            link_c2s.send(f, t)
        inbound = link_c2s.deliver(t)
        if inbound:
            for f in server.push(inbound, t):
                link_s2c.send(f, t)
        if client.established:
            for f in server.poll(t):
                link_s2c.send(f, t)
        for f in link_s2c.deliver(t):
            for a in client.on_frame(f, t):
                link_c2s.send(a, t)
        if len(client.received) >= len(payload) and server.fully_acked():
            end = t
            break
    complete = (bytes(client.received) == payload) and server.fully_acked()
    adv = client.advance_ticks
    gaps = np.diff(adv) if len(adv) > 1 else np.asarray([0])
    return TransferStats(
        complete=complete, ticks=end, delivered=len(client.received),
        goodput=len(client.received) / max(end, 1),
        p99_gap=float(np.percentile(gaps, 99)) if len(gaps) else 0.0,
        max_gap=int(gaps.max()) if len(gaps) else 0,
        frames_tx=server.frames_tx, dup_acks=client.dup_acks_sent,
        link_stats={"s2c": dict(link_s2c.stats),
                    "c2s": dict(link_c2s.stats)})
