"""In-band management plane (paper §3.6, §4.5, §4.6).

`repro.mgmt.plane` binds a management UDP port into any topology and
registers the `mgmt` tile that decodes/applies control commands inside the
compiled pipeline; `repro.mgmt.console` is the host-side operator client.
"""
from repro.mgmt.plane import DEFAULT_MGMT_PORT, bind_mgmt  # noqa: F401
