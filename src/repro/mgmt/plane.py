"""Management-plane subsystem: the in-band control path (paper §3.6, §4.5,
§4.6).

Control packets are ordinary UDP frames carrying ``MSG_CTRL`` RPC bodies on
a bound management port.  They traverse the *compiled* dataplane pipeline
like any other packet (eth_rx -> ip_rx -> udp_rx -> mgmt -> udp_tx -> ...),
so diagnostics and control are reachable from an unmodified client on the
network — the paper's in-band readback story.  Structurally, the controller
and its per-tile endpoints are declared on a dedicated ``noc="ctrl"``
topology with its own deadlock analysis: control distribution can never
join (or deadlock against) a dataplane chain, and `TopologyConfig.validate`
rejects any route that crosses between the NoCs.

The `mgmt` tile registered here:

  * decodes `(op, target, a, b, c)` commands (`control.decode_command`),
  * applies writes (NAT_SET / ROUTE_SET / HEALTH_SET) **live**: the new
    tables are staged in the carrier and committed by the executor after
    the batch, so the next batch runs with the new configuration — no
    recompile (versioned for convergence polling),
  * serves LOG_READ requests from any tile's telemetry RingLog, with the
    REQ_BUF drop-and-re-request semantics of §4.6,
  * emits a fixed-size response body for every management-port packet, so
    acks and readback rows flow back as standard TX frames.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import control, routing, telemetry
from repro.core.compiler import register_tile
from repro.core.routing import RouteTable
from repro.core.topology import TopologyConfig
from repro.net import bytesops as B
from repro.net import ipv4, rpc

DEFAULT_MGMT_PORT = 9909


# ---------------------------------------------------------------------------
# topology binding (the config edit that turns a stack operable)


def bind_mgmt(topo: TopologyConfig, port: int = DEFAULT_MGMT_PORT,
              targets: Optional[List[str]] = None) -> Dict:
    """Bind a management port into `topo` — pure configuration edits.

    Dataplane side: a `mgmt` tile is parked behind the UDP parser with a
    ``udp_port == port`` route and replies through `udp_tx` (both are added
    if the stack has none, e.g. a TCP stack: management stays UDP, §4.6).
    Control side: a controller tile plus one `‹tile›.m` endpoint per managed
    tile are declared on ``noc="ctrl"`` with their own chains, so the
    control-distribution paths get an independent deadlock analysis."""
    base_x = topo.dim_x
    topo.dim_x += 2

    # has_node: a replicated parser (an RSS group named "udp_rx") counts
    # as bound — the port route lands on every member, the chain expands
    if not topo.has_node("udp_rx"):
        topo.add_tile("udp_rx", "udp_rx", base_x, 0)
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_UDP, "udp_rx")
    if not topo.has_tile("udp_tx"):
        topo.add_tile("udp_tx", "udp_tx", base_x, 1)
        topo.add_route("udp_tx", "const", None, "ip_tx")

    topo.add_tile("mgmt", "mgmt", base_x + 1, 0)
    topo.add_route("udp_rx", "udp_port", port, "mgmt")
    topo.add_route("mgmt", "const", None, "udp_tx")
    topo.add_chain("eth_rx", "ip_rx", "udp_rx", "mgmt", "udp_tx",
                   "ip_tx", "eth_tx")
    # every dataplane tile gets a management endpoint; the mgmt tile's own
    # ctrl-NoC interface is `ctrl_in` (same coordinate), not an endpoint
    if targets is None:
        targets = [t.name for t in topo.tiles_on("data")
                   if t.name != "mgmt"]

    # ---- ctrl NoC: controller + per-tile management endpoints ------------
    ctrl = next((t.name for t in topo.tiles_on("ctrl")
                 if t.kind == "controller"), None)
    if ctrl is None:
        ctrl = "ctrl"
        topo.add_tile("ctrl", "controller", base_x + 1, 1, noc="ctrl")
    topo.add_tile("ctrl_in", "ctrl_in", base_x + 1, 0, noc="ctrl")
    topo.add_route("ctrl_in", "const", None, ctrl)
    topo.add_chain("ctrl_in", ctrl)
    for k, tname in enumerate(targets):
        td = topo.tile(tname)
        ep = f"{tname}.m"
        topo.add_tile(ep, "mgmt_ep", td.x, td.y, noc="ctrl")
        topo.add_route(ctrl, "tile", k, ep)       # config write delivery
        topo.add_chain(ctrl, ep)
        # the readback *response* path (endpoint -> controller) is a
        # message chain, not a forwarding route: it must be modeled in the
        # deadlock analysis, but routes stay a tree so the ctrl pipeline
        # compiles as a DAG
        topo.add_chain(ep, ctrl)
    return {"port": port, "mgmt": "mgmt", "ctrl_in": "ctrl_in",
            "controller": ctrl, "targets": list(targets)}


# ---------------------------------------------------------------------------
# ctrl-NoC structural tiles (distribution endpoints; no packet processing)


@register_tile("ctrl_in")
def ctrl_in_tile(state, carrier, pred, ctx):
    """Injection point where the dataplane mgmt tile hands decoded commands
    onto the management NoC."""
    return state, carrier, None


@register_tile("mgmt_ep")
def mgmt_ep_tile(state, carrier, pred, ctx):
    """Per-tile management endpoint: receives table writes, sources log
    readbacks (structural — the executor applies writes centrally)."""
    return state, carrier, None


# ---------------------------------------------------------------------------
# the management tile (compiled into the dataplane pipeline)


def _mgmt_init(ctx):
    return {"mgmt": {"ctrl": control.make_controller()}}


@register_tile("mgmt", init=_mgmt_init)
def mgmt_tile(state, carrier, pred, ctx):
    """Decode + apply + respond, vectorized over the batch.

    Commands are processed in batch order under one `lax.scan` (the version
    counter is strictly ordered, like the paper's serialized management
    NoC).  Table writes are *staged* into ``carrier["mgmt_staged"]`` and
    committed by the executor after the batch — the ack a client receives
    is the promise that the *next* batch sees the new tables."""
    pm = ctx.pipe
    meta = carrier["meta"]
    body, blen = carrier["body"], carrier["blen"]
    nb = body.shape[0]

    valid = (pred & (meta["msg_type"] == rpc.MSG_CTRL)
             & (blen >= control.CMD_BYTES))
    words = jnp.stack([B.be32(body, 4 * i)
                       for i in range(control.CMD_WORDS)], axis=1)  # (B, 5)

    # ---- gather the managed tables -----------------------------------
    has_nat = "nat" in state
    nat_virt = state["nat"]["virt"] if has_nat else jnp.zeros((1,), jnp.uint32)
    nat_phys = state["nat"]["phys"] if has_nat else jnp.zeros((1,), jnp.uint32)

    groups = [g for g in pm["groups"] if g in state.get("dispatch", {})]
    healthy0 = tuple(state["dispatch"][g].healthy for g in groups)
    # GROUP_READ serves served-counter snapshots (totals through the
    # previous batch — the dispatch tiles run before mgmt sees traffic)
    served0 = tuple(state["dispatch"][g].served for g in groups)

    rts = state.get("routes") or {}
    tnames = [t for t in pm["tables"] if t in rts]
    n_tables = len(tnames)
    slots = routing.TABLE_SLOTS
    tkeys0 = (jnp.stack([rts[t].keys for t in tnames]) if n_tables
              else jnp.zeros((1, slots), jnp.int32))
    tvals0 = (jnp.stack([rts[t].values for t in tnames]) if n_tables
              else jnp.zeros((1, slots), jnp.int32))

    telem = state.get("telemetry")
    # canonical log-id namespace (shared with MgmtConsole): pipeline nodes
    # first (rows live stacked in telemetry["nodes"] — one slice per node,
    # written as a single block at batch egress, so LOG_READ serves rows
    # *through the previous batch*), then extra logs — e.g. the
    # per-connection tcp_cc.* CC logs, which their tiles append inline
    nodes = (telem or {}).get("nodes")
    extras = sorted((telem or {}).get("logs", {}))
    node_names = list(pm["order"]) if nodes is not None else []
    n_nodes = len(node_names)
    n_logs = len(telemetry.log_order(node_names, extras))
    blocks_e, blocks_w = [], []
    if nodes is not None:
        blocks_e.append(jnp.moveaxis(nodes.entries, 0, 1))
        blocks_w.append(jnp.broadcast_to(nodes.wr, (n_nodes,)))
    if extras:
        blocks_e.append(jnp.stack([telem["logs"][n].entries
                                   for n in extras]))
        blocks_w.append(jnp.stack([telem["logs"][n].wr for n in extras]))
    ents = (jnp.concatenate(blocks_e) if blocks_e
            else jnp.zeros((1, 1, telemetry.LOG_WIDTH), jnp.int32))
    wrs = (jnp.concatenate(blocks_w) if blocks_w
           else jnp.zeros((1,), jnp.int32))

    # observability tables (snapshot reads: whatever the executor wrote at
    # the *previous* batch's egress — same staleness window as LOG_READ)
    obsb = (telem or {}).get("obs")
    has_obs = obsb is not None
    histo0 = (obsb["histo"] if has_obs
              else jnp.zeros((1, control.OBS_ROW_WORDS), jnp.int32))
    dropt = (telem or {}).get("drops")
    has_drops = dropt is not None
    drops0 = dropt if has_drops else jnp.zeros((1, 1), jnp.int32)

    # dispatch-side token buckets + congestion-control knobs (if present)
    has_rate = "rate" in state
    rate0 = (state["rate"] if has_rate
             else {k: jnp.zeros((1,), jnp.int32)
                   for k in ("ports", "rate", "burst", "tokens")})
    cc0 = (state.get("conn") or {}).get("cc")
    has_cc = cc0 is not None
    cc_cwnd0 = cc0["cwnd"] if has_cc else jnp.zeros((1,), jnp.int32)
    cc_ssth0 = cc0["ssthresh"] if has_cc else jnp.zeros((1,), jnp.int32)
    cc_pol0 = cc0["policy"] if has_cc else jnp.zeros((), jnp.int32)

    # push-mode observability: the series ring (snapshot reads) and the
    # watchdog rule table (staged writes, like every other table)
    serb = (telem or {}).get("series")
    has_series = serb is not None
    ring0 = (serb["ring"] if has_series else jnp.zeros((1, 1, 1), jnp.int32))
    ser_wr0 = serb["wr"] if has_series else jnp.zeros((), jnp.int32)
    slo0 = state.get("slo")
    has_slo = slo0 is not None
    zr = jnp.zeros((1,), jnp.int32)

    ctrlst = state["mgmt"]["ctrl"]
    carry0 = {
        "version": ctrlst.version, "last_op": ctrlst.last_op,
        "acks": ctrlst.acks,
        "nat_virt": nat_virt, "nat_phys": nat_phys,
        "healthy": healthy0,
        "tkeys": tkeys0, "tvals": tvals0,
        "rate": dict(rate0),
        "cc_cwnd": cc_cwnd0, "cc_ssth": cc_ssth0, "cc_pol": cc_pol0,
        "obs_en": (obsb["ctrl"]["enable"] if has_obs
                   else jnp.zeros((), jnp.int32)),
        "obs_shift": (obsb["ctrl"]["shift"] if has_obs
                      else jnp.zeros((), jnp.int32)),
        "slo_metric": slo0["metric"] if has_slo else zr,
        "slo_node": slo0["node"] if has_slo else zr,
        "slo_raise": slo0["thr_raise"] if has_slo else zr,
        "slo_clear": slo0["thr_clear"] if has_slo else zr,
        "slo_en": slo0["enabled"] if has_slo else zr,
        # slots rewritten this batch get unlatched at commit
        "slo_reset": jnp.zeros_like(slo0["enabled"] if has_slo else zr),
        "win_len": (serb["win_len"] if has_series
                    else jnp.zeros((), jnp.int32)),
        # outstanding readbacks were serviced between batches (drain)
        "fills": jnp.zeros((max(n_logs, 1),), jnp.int32),
    }

    # a range response must fit the reply body: never serve more rows
    # than the carrier can carry back (the served count IS the layout)
    body_w = carrier["out_body"].shape[1]
    max_fit = max(0, min(control.MAX_RANGE,
                         (body_w - 12) // (4 * control.ROW_WORDS)))

    def step(c, xs):
        w, v = xs
        cmd = control.decode_command(w)
        op, target = cmd["op"], cmd["target"]
        a, b, cc = cmd["a"], cmd["b"], cmd["c"]

        # NAT_SET — rewrite one virtual->physical mapping
        is_nat = v & (op == control.OP_NAT_SET) & has_nat
        s_nat = jnp.clip(a, 0, c["nat_virt"].shape[0] - 1)
        nat_ok = is_nat & (a >= 0) & (a < c["nat_virt"].shape[0])
        nv = c["nat_virt"].at[s_nat].set(b.astype(jnp.uint32))
        np_ = c["nat_phys"].at[s_nat].set(cc.astype(jnp.uint32))
        nat_virt = jnp.where(nat_ok, nv, c["nat_virt"])
        nat_phys = jnp.where(nat_ok, np_, c["nat_phys"])

        # HEALTH_SET — drain/restore one replica of one dispatch group
        hs, health_ok = [], jnp.zeros((), bool)
        for gi, h in enumerate(c["healthy"]):
            apply_h = (v & (op == control.OP_HEALTH_SET) & (target == gi)
                       & (a >= 0) & (a < h.shape[0]))
            idx = jnp.clip(a, 0, h.shape[0] - 1)
            hs.append(jnp.where(apply_h, h.at[idx].set(b != 0), h))
            health_ok = health_ok | apply_h
        healthy = tuple(hs)

        # ROUTE_SET — rewrite one CAM slot of one routing table
        is_route = v & (op == control.OP_ROUTE_SET) & (n_tables > 0)
        route_ok = (is_route & (target >= 0) & (target < n_tables)
                    & (a >= 0) & (a < slots))
        ti = jnp.clip(target, 0, max(n_tables - 1, 0))
        si = jnp.clip(a, 0, slots - 1)
        tk = c["tkeys"].at[ti, si].set(b.astype(jnp.int32))
        tv = c["tvals"].at[ti, si].set(cc.astype(jnp.int32))
        tkeys = jnp.where(route_ok, tk, c["tkeys"])
        tvals = jnp.where(route_ok, tv, c["tvals"])

        # RATE_SET — install / clear one dispatch token bucket
        rt = c["rate"]
        n_slots = rt["ports"].shape[0]
        is_rate = v & (op == control.OP_RATE_SET) & has_rate
        rate_ok = is_rate & (a >= 0) & (a < n_slots)
        rs = jnp.clip(a, 0, n_slots - 1)
        clear = b == -1
        new_port = jnp.where(clear, -1, b)
        new_rate = jnp.where(clear, 0, cc & 0xFFFF)
        new_burst = jnp.where(clear, 0,
                              jnp.where(((cc >> 16) & 0xFFFF) > 0,
                                        (cc >> 16) & 0xFFFF, cc & 0xFFFF))
        rate = {
            "ports": jnp.where(rate_ok, rt["ports"].at[rs].set(new_port),
                               rt["ports"]),
            "rate": jnp.where(rate_ok, rt["rate"].at[rs].set(new_rate),
                              rt["rate"]),
            "burst": jnp.where(rate_ok, rt["burst"].at[rs].set(new_burst),
                               rt["burst"]),
            # a rewritten bucket starts full
            "tokens": jnp.where(rate_ok, rt["tokens"].at[rs].set(new_burst),
                                rt["tokens"]),
        }

        # CC_SET — live congestion-control knobs (engine must have CC)
        is_cc = v & (op == control.OP_CC_SET) & has_cc
        n_conns = c["cc_cwnd"].shape[0]
        conn_ok = (target >= 0) & (target < n_conns)
        ci = jnp.clip(target, 0, n_conns - 1)
        pol_ok = is_cc & (a == 0) & ((b == 0) | (b == 1))
        cwnd_ok = is_cc & (a == 1) & conn_ok & (b > 0)
        ssth_ok = is_cc & (a == 2) & conn_ok & (b > 0)
        cc_pol = jnp.where(pol_ok, b, c["cc_pol"])
        cc_cwnd = jnp.where(cwnd_ok, c["cc_cwnd"].at[ci].set(b),
                            c["cc_cwnd"])
        cc_ssth = jnp.where(ssth_ok, c["cc_ssth"].at[ci].set(b),
                            c["cc_ssth"])
        cc_ok = pol_ok | cwnd_ok | ssth_ok

        # TRACE_SET — flight-recorder knobs: both are runtime state, so
        # the sampling modulus changes with no retrace; staged like any
        # table write, live next batch
        is_trace = v & (op == control.OP_TRACE_SET) & has_obs
        trace_ok = is_trace & (b >= 0) & (b < 16)
        obs_en = jnp.where(trace_ok, (a != 0).astype(jnp.int32),
                           c["obs_en"])
        obs_shift = jnp.where(trace_ok, b, c["obs_shift"])

        # SLO_SET — install / clear one watchdog rule over the series
        # ring (target = rule slot; a = metric<<16 | node; b = raise
        # threshold, -1 disables the slot; c = clear threshold).
        # target == -1 with b > 0 sets the series window length instead.
        n_rules = c["slo_metric"].shape[0]
        is_slo = v & (op == control.OP_SLO_SET)
        rule_ok = is_slo & has_slo & (target >= 0) & (target < n_rules)
        ri = jnp.clip(target, 0, n_rules - 1)
        disable = b == -1
        met = (a >> 16) & 0xFFFF
        nod = a & 0xFFFF
        slo_metric = jnp.where(rule_ok & ~disable,
                               c["slo_metric"].at[ri].set(met),
                               c["slo_metric"])
        slo_node = jnp.where(rule_ok & ~disable,
                             c["slo_node"].at[ri].set(nod), c["slo_node"])
        slo_raise = jnp.where(rule_ok & ~disable,
                              c["slo_raise"].at[ri].set(b), c["slo_raise"])
        slo_clear = jnp.where(rule_ok & ~disable,
                              c["slo_clear"].at[ri].set(cc), c["slo_clear"])
        slo_en = jnp.where(rule_ok,
                           c["slo_en"].at[ri].set(
                               jnp.where(disable, 0, 1)), c["slo_en"])
        slo_reset = jnp.where(rule_ok, c["slo_reset"].at[ri].set(1),
                              c["slo_reset"])
        win_ok = is_slo & has_series & (target == -1) & (b > 0)
        win_len = jnp.where(win_ok, b, c["win_len"])
        slo_ok = rule_ok | win_ok

        # HISTO_READ / DROP_READ / SERIES_READ — one snapshot table row
        # each, served in the wide (range-layout) response frame
        want_h = v & (op == control.OP_HISTO_READ) & has_obs
        hrow, hserved = control.serve_table_row(histo0, a, want_h)
        want_d = v & (op == control.OP_DROP_READ) & has_drops
        drow, dserved = control.serve_table_row(drops0, a, want_d)
        want_s = v & (op == control.OP_SERIES_READ) & has_series
        srow, sserved = control.serve_series_row(
            ring0, ser_wr0, c["win_len"], a, target, want_s)

        # GROUP_READ — one replica group's healthy bitmap (live, from the
        # scan carry: a HEALTH_SET earlier in this batch is visible) plus
        # per-replica served counters (snapshot), wide-response layout
        want_g = v & (op == control.OP_GROUP_READ) & (len(groups) > 0)
        grow = jnp.zeros((control.OBS_ROW_WORDS,), jnp.uint32)
        gserved = jnp.zeros((), jnp.int32)
        for gi in range(len(groups)):
            r_, s_ = control.serve_group_row(
                c["healthy"][gi], served0[gi], want_g & (target == gi))
            grow = grow | r_
            gserved = gserved | s_

        want_obs = want_h | want_d | want_s | want_g
        obs_served = jnp.where(
            want_h, hserved,
            jnp.where(want_d, dserved,
                      jnp.where(want_g, gserved, sserved)))

        # LOG_READ — serve a counter row, REQ_BUF backpressure
        want = v & (op == control.OP_LOG_READ) & (n_logs > 0)
        fills, row, accepted = control.serve_log_read(
            ents, wrs, c["fills"], a, b.astype(jnp.int32), want)

        # LOG_READ_RANGE — bulk streaming: many rows, one response frame
        want_rng = v & (op == control.OP_LOG_READ_RANGE) & (n_logs > 0)
        fills, rng_rows, served = control.serve_log_read_range(
            ents, wrs, fills, a, b.astype(jnp.int32),
            jnp.minimum(cc.astype(jnp.int32), max_fit), want_rng)

        is_ver = v & (op == control.OP_VERSION)
        applied = nat_ok | health_ok | route_ok | rate_ok | cc_ok \
            | trace_ok | slo_ok
        version = c["version"] + applied.astype(jnp.int32)
        status = (applied | accepted | is_ver).astype(jnp.uint32)
        plain = control.encode_response(w[0], version, status, row)
        plain = jnp.concatenate([
            plain, jnp.zeros((control.RANGE_RESP_WORDS
                              - control.RESP_WORDS,), jnp.uint32)])
        rng = control.encode_range_response(w[0], version, served, rng_rows)
        wide = control.encode_obs_response(
            w[0], version, obs_served,
            jnp.where(want_h, hrow,
                      jnp.where(want_d, drow,
                                jnp.where(want_g, grow, srow))))
        resp = jnp.where(want_rng, rng, jnp.where(want_obs, wide, plain))
        blen = jnp.where(
            want_rng,
            12 + 4 * control.ROW_WORDS * served,
            jnp.where(want_obs, 12 + 4 * obs_served,
                      jnp.full_like(served,
                                    control.RESP_BYTES))).astype(jnp.int32)

        nc = {"version": version,
              "last_op": jnp.where(applied, op, c["last_op"]),
              "acks": c["acks"] + v.astype(jnp.int32),
              "nat_virt": nat_virt, "nat_phys": nat_phys,
              "healthy": healthy, "tkeys": tkeys, "tvals": tvals,
              "rate": rate,
              "cc_cwnd": cc_cwnd, "cc_ssth": cc_ssth, "cc_pol": cc_pol,
              "obs_en": obs_en, "obs_shift": obs_shift,
              "slo_metric": slo_metric, "slo_node": slo_node,
              "slo_raise": slo_raise, "slo_clear": slo_clear,
              "slo_en": slo_en, "slo_reset": slo_reset,
              "win_len": win_len,
              "fills": fills}
        return nc, (resp, blen)

    carry, (resps, blens) = jax.lax.scan(step, carry0, (words, valid))

    # ---- responses: ack / readback bodies (range reads are longer) ----
    rb = carrier["out_body"]
    body_w = rb.shape[1]
    for i in range(control.RANGE_RESP_WORDS):
        if 4 * (i + 1) <= body_w:
            rb = B.set_be32(rb, 4 * i, resps[:, i])
    carrier["out_body"] = jnp.where(pred[:, None], rb, carrier["out_body"])
    carrier["out_blen"] = jnp.where(
        pred, jnp.minimum(blens, body_w), carrier["out_blen"])
    info = dict(carrier["info"])
    info["mgmt"] = pred
    carrier["info"] = info

    # ---- persist controller state + request-buffer fills --------------
    state = dict(state)
    state["mgmt"] = {"ctrl": control.ControllerState(
        version=carry["version"], last_op=carry["last_op"],
        acks=carry["acks"])}
    if telem is not None:
        if nodes is not None:
            # in-place into the executor's per-run telemetry dict, like
            # the tile-contributed logs: the executor appends this
            # batch's row block to exactly this object after the stages
            telem["nodes"] = dataclasses.replace(
                nodes, req_fill=carry["fills"][:n_nodes])
        for j, nme in enumerate(extras):
            telem["logs"][nme] = dataclasses.replace(
                telem["logs"][nme], req_fill=carry["fills"][n_nodes + j])

    # ---- stage table writes for the executor's post-batch commit ------
    staged = {"healthy": {g: h for g, h in zip(groups, carry["healthy"])}}
    if has_nat:
        staged["nat"] = {"virt": carry["nat_virt"],
                         "phys": carry["nat_phys"]}
    if n_tables:
        staged["routes"] = dict(rts)
        for i, t in enumerate(tnames):
            staged["routes"][t] = RouteTable(
                keys=carry["tkeys"][i], values=carry["tvals"][i],
                default=rts[t].default)
    if has_rate:
        staged["rate"] = carry["rate"]
    if has_cc:
        # full cc block with the knob writes folded in: the mgmt tile runs
        # after tcp_rx (declaration order), so this batch's ACK-driven
        # updates are already in cc0 and survive the commit
        cc_new = dict(cc0)
        cc_new["cwnd"] = carry["cc_cwnd"]
        cc_new["ssthresh"] = carry["cc_ssth"]
        cc_new["policy"] = carry["cc_pol"]
        staged["cc"] = cc_new
    if has_obs:
        staged["obs_ctrl"] = {"enable": carry["obs_en"],
                              "shift": carry["obs_shift"]}
    if has_slo:
        # rule fields only (+ an unlatch mask for rewritten slots): the
        # watchdog's own active/last_wr updates happen at egress, after
        # this tile ran, and must survive the commit
        staged["slo"] = {"metric": carry["slo_metric"],
                         "node": carry["slo_node"],
                         "thr_raise": carry["slo_raise"],
                         "thr_clear": carry["slo_clear"],
                         "enabled": carry["slo_en"],
                         "clear_active": carry["slo_reset"]}
    if has_series:
        staged["series_win"] = carry["win_len"]
    carrier["mgmt_staged"] = staged
    return state, carrier, None
