"""Operator console: the host-side management client (paper §4.6's
"unmodified Linux client" for the control plane).

Crafts management command frames (standard Ethernet/IPv4/UDP + RPC with
``MSG_CTRL`` bodies), feeds them through a management-bound stack, and
parses the ack / readback frames that come back down the TX chain.  All
host-side work is numpy/struct — the console talks to the stack the same
way a remote operator box would talk to the accelerator.
"""
from __future__ import annotations

import struct
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import control, telemetry
from repro.net import frames as F
from repro.net import rpc
from repro.transport import cc as ccmod

ETH_HLEN, IP_HLEN, UDP_HLEN = 14, 20, 8


def command_frame(src_ip: int, dst_ip: int, src_port: int, mgmt_port: int,
                  op: int, target: int = 0, a: int = 0, b: int = 0,
                  c: int = 0, req_id: int = 0) -> bytes:
    """One wire-format management command frame."""
    body = struct.pack("!5I", op & 0xFFFFFFFF, target & 0xFFFFFFFF,
                       a & 0xFFFFFFFF, b & 0xFFFFFFFF, c & 0xFFFFFFFF)
    return F.udp_rpc_frame(src_ip, dst_ip, src_port, mgmt_port,
                           rpc.np_frame(rpc.MSG_CTRL, req_id, body))


def parse_response(frame: bytes) -> Dict:
    """Parse one management reply frame into {op, version, status, row,
    req_id}.  `row` is the LOG_READ counter payload [step, packets_in,
    drops, noc_latency, tile_index].  Replies may be Ethernet- or
    IP-level (`frames.l2_offset` disambiguates)."""
    rpc_off = F.l2_offset(frame) + IP_HLEN + UDP_HLEN
    req_id = struct.unpack_from("!I", frame, rpc_off + 3)[0]
    body = rpc_off + rpc.HLEN
    nwords = min(control.RESP_WORDS, (len(frame) - body) // 4)
    w = list(struct.unpack_from(f"!{nwords}I", frame, body))
    w += [0] * (control.RESP_WORDS - nwords)   # dropped range: 3-word body
    out = {"op": w[0], "version": w[1], "status": w[2],
           "row": {"step": w[3], "packets_in": w[4], "drops": w[5],
                   "noc_latency": w[6], "tile_index": w[7]},
           "req_id": req_id}
    if w[0] == control.OP_LOG_READ_RANGE:
        # bulk readback: status = served row count, then 5 words per row
        served = min(w[2], control.MAX_RANGE)
        rows = []
        for k in range(served):
            rows.append(list(struct.unpack_from(
                "!5I", frame, body + 12 + 4 * control.ROW_WORDS * k)))
        out["rows"] = rows
        out["row"] = {}
    elif w[0] in (control.OP_HISTO_READ, control.OP_DROP_READ,
                  control.OP_SERIES_READ, control.OP_GROUP_READ):
        # snapshot table row: status = served word count, then the row
        served = min(w[2], control.OBS_ROW_WORDS)
        out["table_row"] = list(struct.unpack_from(
            f"!{served}I", frame, body + 12)) if served else []
        out["row"] = {}
    return out


class MgmtConsole:
    """Drives one management-bound stack (`UdpStack` / `TcpStack` with
    ``mgmt_port=...``).  Name→id resolution comes from the compiled
    pipeline's metadata, so the console never hardcodes the topology."""

    def __init__(self, stack, client_ip: Optional[int] = None,
                 client_port: int = 5999):
        if getattr(stack, "mgmt_port", None) is None:
            raise ValueError("stack has no management port binding "
                             "(construct it with mgmt_port=...)")
        self.stack = stack
        self.port = stack.mgmt_port
        self.client_ip = client_ip if client_ip is not None \
            else F.ip("10.0.9.9")
        self.client_port = client_port
        self._req_id = 0
        pipe = getattr(stack, "pipeline", None) or stack.rx_pipe
        meta = pipe.pipe_meta
        self.node_ids = {n: i for i, n in enumerate(meta["order"])}
        self.group_ids = {g: i for i, g in enumerate(meta["groups"])}
        self.table_ids = {t: i for i, t in enumerate(meta["tables"])}

    # ---- transport -------------------------------------------------------
    def roundtrip(self, state, cmds: Sequence[Tuple[int, int, int, int, int]]
                  ) -> Tuple[Dict, List[Dict]]:
        """Send one batch of (op, target, a, b, c) commands; returns
        (state', responses) in command order."""
        frames = []
        ids = []
        for (op, target, a, b, c) in cmds:
            self._req_id += 1
            ids.append(self._req_id)
            frames.append(command_frame(
                self.client_ip, self.stack.local_ip, self.client_port,
                self.port, op, target, a, b, c, req_id=self._req_id))
        payload, length = F.to_batch(frames, 256)
        payload, length = jnp.asarray(payload), jnp.asarray(length)
        if hasattr(self.stack, "rx_tx"):                       # UDP stack
            state, q, ql, alive, info = self.stack.rx_tx(
                state, payload, length)
            mask = np.asarray(alive & info["mgmt"])
        else:                                                  # TCP stack
            state, _resps, q, ql, mask = self.stack.rx_mgmt(
                state, payload, length)
            mask = np.asarray(mask)
        q, ql = np.asarray(q), np.asarray(ql)
        out = []
        for i in range(len(frames)):
            if not mask[i]:
                out.append({"op": 0, "version": 0, "status": 0,
                            "row": {}, "req_id": ids[i], "lost": True})
                continue
            out.append(parse_response(bytes(q[i, :ql[i]].tobytes())))
        return state, out

    # ---- write operations ------------------------------------------------
    def set_nat(self, state, slot: int, virtual_ip: int, physical_ip: int):
        """Rewrite one NAT mapping; the next batch translates with it."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_NAT_SET, 0, slot, virtual_ip, physical_ip)])
        return state, r

    def set_route(self, state, table: str, slot: int, key: int,
                  next_node: str):
        """Rewrite one CAM slot (e.g. bind a new UDP port to an app)."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_ROUTE_SET, self.table_ids[table], slot, key,
             self.node_ids[next_node])])
        return state, r

    def drain_replica(self, state, group: str, replica: int):
        """Mark one app replica down: dispatch stops selecting it."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_HEALTH_SET, self.group_ids[group], replica, 0, 0)])
        return state, r

    def restore_replica(self, state, group: str, replica: int):
        state, (r,) = self.roundtrip(state, [
            (control.OP_HEALTH_SET, self.group_ids[group], replica, 1, 0)])
        return state, r

    def set_rate(self, state, slot: int, port: int, rate: int,
                 burst: Optional[int] = None):
        """Install a per-port token bucket at the dispatch tile: `rate`
        packets per batch, bucket capacity `burst` (default = rate)."""
        packed = (rate & 0xFFFF) | (((burst or 0) & 0xFFFF) << 16)
        state, (r,) = self.roundtrip(state, [
            (control.OP_RATE_SET, 0, slot, port, packed)])
        return state, r

    def clear_rate(self, state, slot: int):
        """Remove one token bucket: the port becomes unlimited again."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_RATE_SET, 0, slot, -1, 0)])
        return state, r

    def set_cc_policy(self, state, policy: str):
        """Switch the TCP engine's congestion-control policy live."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_CC_SET, 0, 0, ccmod.POLICIES[policy], 0)])
        return state, r

    def set_cc_window(self, state, conn: int, cwnd: Optional[int] = None,
                      ssthresh: Optional[int] = None):
        """Override one connection's cwnd and/or ssthresh."""
        cmds = []
        if cwnd is not None:
            cmds.append((control.OP_CC_SET, conn, 1, cwnd, 0))
        if ssthresh is not None:
            cmds.append((control.OP_CC_SET, conn, 2, ssthresh, 0))
        state, rs = self.roundtrip(state, cmds)
        return state, rs

    # ---- readback --------------------------------------------------------
    def log_ids(self, state) -> Dict[str, int]:
        """The runtime log-id namespace: node logs first (id == node
        index; rows come from the stacked `telemetry["nodes"]` log), then
        extra logs (per-connection CC logs) — the same order the compiled
        mgmt tile serves (`telemetry.log_order`)."""
        telem = state.get("telemetry", {})
        nodes = list(self.node_ids) if "nodes" in telem else []
        order = telemetry.log_order(nodes, telem.get("logs", {}))
        return {n: i for i, n in enumerate(order)}

    def read_counters(self, state, tile: str, age: int = 0):
        """One tile's telemetry counter row, `age` batches back."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_LOG_READ, 0, self.node_ids[tile], age, 0)])
        return state, r

    def read_log_range(self, state, tile: str, start: int = 0,
                       count: int = control.MAX_RANGE):
        """Bulk counter streaming: up to MAX_RANGE rows (newest-first
        from age `start`) of one log in a single in-band round trip."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_LOG_READ_RANGE, 0, self.log_ids(state)[tile],
             start, count)])
        return state, r

    def read_cc(self, state, conn: int, age: int = 0):
        """One connection's congestion-control counters (cwnd, ssthresh,
        srtt, retx, marks) from its tcp_cc.<conn> RingLog."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_LOG_READ, 0,
             self.log_ids(state)[ccmod.log_name(conn)], age, 0)])
        if r["status"] == 1:
            row = r["row"]
            r["cc"] = ccmod.unpack_row([row["step"], row["packets_in"],
                                        row["drops"], row["noc_latency"],
                                        row["tile_index"]])
        return state, r

    def set_trace(self, state, enable: bool, shift: int = 6):
        """Flight-recorder control: record 1 in 2**shift frames when
        enabled.  Runtime state only — takes effect next batch, and the
        sampling rate changes with NO retrace of the compiled stream."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_TRACE_SET, 0, int(bool(enable)), shift, 0)])
        return state, r

    def read_histo(self, state, tile: Optional[str] = None):
        """One occupancy-histogram row (16 power-of-two buckets) from the
        device: a tile's per-stage occupancy, or the end-to-end row when
        `tile` is None.  Served through the previous batch."""
        row_id = len(self.node_ids) if tile is None else self.node_ids[tile]
        state, (r,) = self.roundtrip(state, [
            (control.OP_HISTO_READ, 0, row_id, 0, 0)])
        return state, r

    def read_drops(self, state, tile: str):
        """One tile's drop-reason counts as {reason_name: count} (nonzero
        only).  Served through the previous batch."""
        from repro.obs import reasons
        state, (r,) = self.roundtrip(state, [
            (control.OP_DROP_READ, 0, self.node_ids[tile], 0, 0)])
        if r.get("table_row"):
            r["reasons"] = {reasons.name(i): c
                            for i, c in enumerate(r["table_row"]) if c}
        return state, r

    def read_group(self, state, group: str):
        """One replica group's live state: healthy replicas + per-replica
        served-packet counters (RSS balance check).  The healthy bitmap
        is live — a drain earlier in the same batch is visible; served
        counters run through the previous batch, like LOG_READ."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_GROUP_READ, self.group_ids[group], 0, 0, 0)])
        tr = r.get("table_row") or []
        if len(tr) >= 2:
            n = tr[0]
            r["group"] = {
                "n_replicas": n,
                "healthy": [bool((tr[1] >> i) & 1) for i in range(n)],
                "served": tr[2:2 + n],
            }
        return state, r

    def set_slo(self, state, slot: int, metric, node, raise_thr: int,
                clear_thr: Optional[int] = None):
        """Install one watchdog rule (repro.obs.slo): alert when `metric`
        at `node` crosses `raise_thr` in a series window, latch until it
        falls back to `clear_thr` (default: raise/2).  Live next batch,
        no retrace."""
        from repro.obs import series as series_mod
        mid = (series_mod.METRIC_IDS[metric] if isinstance(metric, str)
               else int(metric))
        nid = self.node_ids[node] if isinstance(node, str) else int(node)
        if clear_thr is None:
            clear_thr = raise_thr // 2
        state, (r,) = self.roundtrip(state, [
            (control.OP_SLO_SET, slot, (mid << 16) | nid,
             int(raise_thr), int(clear_thr))])
        return state, r

    def clear_slo(self, state, slot: int):
        """Disable one watchdog rule slot."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_SLO_SET, slot, 0, -1, 0)])
        return state, r

    def set_window(self, state, batches: int):
        """Set the series window length (batches per window) live."""
        state, (r,) = self.roundtrip(state, [
            (control.OP_SLO_SET, -1, 0, int(batches), 0)])
        return state, r

    def read_series(self, state, tile, age: int = 0):
        """One node's counter deltas for one completed series window
        (age 0 = newest).  Served through the previous batch."""
        from repro.obs import series as series_mod
        nid = self.node_ids[tile] if isinstance(tile, str) else int(tile)
        state, (r,) = self.roundtrip(state, [
            (control.OP_SERIES_READ, nid, age, 0, 0)])
        tr = r.get("table_row") or []
        if len(tr) >= 2 + series_mod.NUM_METRICS:
            r["series"] = {"windows": tr[0], "win_len": tr[1]}
            for i, m in enumerate(series_mod.METRICS):
                r["series"][m] = tr[2 + i]
        return state, r

    def version(self, state) -> Tuple[Dict, int]:
        state, (r,) = self.roundtrip(state, [(control.OP_VERSION, 0, 0, 0, 0)])
        return state, r["version"]

    def wait_converged(self, state, target_version: int,
                       max_polls: int = 8) -> Tuple[Dict, bool]:
        """Poll the version counter until the stack reports convergence."""
        for _ in range(max_polls):
            state, v = self.version(state)
            if v >= target_version:
                return state, True
        return state, False


def dump_counters(stack, state, age: int = 0) -> Tuple[Dict, Dict[str, Dict]]:
    """Read every tile's counter row over the management port.  Each tile's
    log has its own request buffer, so one batch of LOG_READs (one per
    tile) never overflows REQ_BUF."""
    con = MgmtConsole(stack)
    tiles = list(con.node_ids)
    state, resps = con.roundtrip(state, [
        (control.OP_LOG_READ, 0, con.node_ids[t], age, 0) for t in tiles])
    return state, {t: r["row"] for t, r in zip(tiles, resps)
                   if r["status"] == 1}
