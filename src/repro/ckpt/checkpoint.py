"""Sharded checkpointing with atomic commit, async snapshot, and elastic
resharding on restore.

Layout: <dir>/step_<n>/
          manifest.json    tree structure, shapes, dtypes
          <leaf-id>.npy    one file per leaf (host-gathered)
        <dir>/LATEST       committed step marker (atomic rename)

Restore takes optional target shardings: the same checkpoint re-lays-out
onto any mesh (pod count changes, replica loss — the trainer's elastic
restart path).  `AsyncCheckpointer` snapshots to host memory synchronously
(cheap) and writes in a background thread so the train loop never blocks
on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(directory: str, step: int, tree: Any) -> str:
    """Synchronous sharded save with atomic commit."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(x) for x in leaves]
    return _write(directory, step, host, treedef)


def _write(directory: str, step: int, host_leaves, treedef) -> str:
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": [{"file": f"leaf_{i}.npy",
                            "shape": list(x.shape), "dtype": str(x.dtype)}
                           for i, x in enumerate(host_leaves)]}
    for i, x in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), x)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    with open(os.path.join(directory, ".LATEST_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, ".LATEST_tmp"),
               os.path.join(directory, "LATEST"))
    return final


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(directory: str, example_tree: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `example_tree`.  When `shardings` (a
    matching pytree of NamedSharding) is given, leaves are device_put with
    the *target* layout — elastic resharding onto a different mesh."""
    step = step if step is not None else latest_step(directory)
    assert step is not None, f"no checkpoint in {directory}"
    d = os.path.join(directory, f"step_{step}")
    leaves, treedef = _flatten(example_tree)
    host = [np.load(os.path.join(d, f"leaf_{i}.npy"))
            for i in range(len(leaves))]
    if shardings is not None:
        sh_leaves, _ = _flatten(shardings)
        host = [jax.device_put(x, s) for x, s in zip(host, sh_leaves)]
    return jax.tree_util.tree_unflatten(treedef, host)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously; write to disk in the background."""

    def __init__(self, directory: str):
        self.directory = directory
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        leaves, treedef = _flatten(tree)
        host = [np.asarray(x) for x in leaves]   # device->host snapshot
        self._thread = threading.Thread(
            target=_write, args=(self.directory, step, host, treedef),
            daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
