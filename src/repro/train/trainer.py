"""Production trainer: jitted step with donation, periodic async
checkpoints, SIGTERM-grace preemption handling, resume, and elastic
restart onto a different mesh.

Fault-tolerance contract:
  * every `ckpt_every` steps the full (params, opt, step) state is
    snapshotted (async — the loop never blocks on disk);
  * SIGTERM/SIGINT triggers a final synchronous checkpoint before exit
    (preemption grace window);
  * `Trainer.restore()` resumes from LATEST; pass a different mesh/policy
    to re-layout the same checkpoint (elastic scaling, node loss);
  * data is cursor-addressed by step (repro.data.pipeline), so restart
    needs no data-state file;
  * stragglers: on a real fleet the control plane marks a replica group
    unhealthy (core/scaleout.mark_health) and the next restart re-shards —
    here that path is exercised by the elastic-restore test.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.data.pipeline import DataConfig, Loader
from repro.launch.steps import make_train_step
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.sharding import SINGLE, Policy


@dataclasses.dataclass
class TrainConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "artifacts/ckpt"
    log_every: int = 10
    microbatches: int = 1
    opt: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 data_cfg: DataConfig, policy: Policy = SINGLE,
                 params=None, key=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.policy = policy
        self.loader = Loader(data_cfg)
        key = key if key is not None else jax.random.key(0)
        self.params = params if params is not None else model.init_params(
            cfg, key)
        self.opt_state = adamw.init(self.params, tcfg.opt)
        self.step = 0
        self._step_fn = jax.jit(
            make_train_step(cfg, policy, tcfg.opt,
                            microbatches=tcfg.microbatches),
            donate_argnums=(0, 1))
        self.ckptr = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
        self._stop = False
        self.metrics_log = []

    # ---- preemption ------------------------------------------------------
    def install_signal_handlers(self):
        def handler(signum, frame):
            self._stop = True
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    # ---- checkpoint / resume ----------------------------------------------
    def state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "step": np.int32(self.step)}

    def save(self, sync: bool = False):
        if sync:
            self.ckptr.wait()
            ckpt.save(self.tcfg.ckpt_dir, self.step, self.state_tree())
        else:
            self.ckptr.save(self.step, self.state_tree())

    def restore(self, shardings=None):
        step = ckpt.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return False
        state = ckpt.restore(self.tcfg.ckpt_dir, self.state_tree(),
                             shardings=shardings)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = int(state["step"])
        return True

    # ---- loop --------------------------------------------------------------
    def run(self, steps: Optional[int] = None) -> Dict[str, Any]:
        end = self.step + steps if steps else self.tcfg.total_steps
        t0 = time.time()
        while self.step < end and not self._stop:
            batch = self.loader.batch(self.step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % self.tcfg.log_every == 0 or self.step == end:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = self.step
                m["wall_s"] = round(time.time() - t0, 2)
                self.metrics_log.append(m)
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
        if self._stop:             # preemption: grace checkpoint
            self.save(sync=True)
        self.ckptr.wait()
        return {"final_step": self.step,
                "log": self.metrics_log}
