"""Deterministic synthetic data pipeline with sharded, resumable loading.

Documents are Zipf-distributed token sequences (seeded -> bit-reproducible
across restarts), packed into fixed-length rows with next-token labels.
`Loader` yields exactly the host's data-parallel slice: on a real cluster
each host feeds its local devices; rank/size come from the mesh.  The
cursor is (step) only — restart resumes from the checkpointed step with no
data-state file needed (fault-tolerance requirement).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_a: float = 1.3
    mean_doc_len: int = 256
    eos_id: int = 0


class SyntheticCorpus:
    """Infinite stream of documents, deterministic per (seed, doc index)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def doc(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(self.cfg.seed * 1_000_003 + i)
        n = int(rng.integers(self.cfg.mean_doc_len // 2,
                             self.cfg.mean_doc_len * 2))
        toks = rng.zipf(self.cfg.zipf_a, n).astype(np.int64)
        toks = (toks % (self.cfg.vocab - 1)) + 1       # reserve 0 for EOS
        return toks.astype(np.int32)


class Loader:
    """Packed next-token batches; shardable by (rank, size)."""

    def __init__(self, cfg: DataConfig, rank: int = 0, size: int = 1):
        assert cfg.global_batch % size == 0
        self.cfg = cfg
        self.rank = rank
        self.size = size
        self.corpus = SyntheticCorpus(cfg)

    def _row(self, row_index: int) -> np.ndarray:
        """Pack documents into one (seq_len + 1) row, deterministic."""
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, np.int32)
        filled = 0
        d = row_index * 7919          # distinct doc stream per row
        while filled < cfg.seq_len + 1:
            doc = self.corpus.doc(d)
            d += 1
            take = min(len(doc), cfg.seq_len + 1 - filled)
            out[filled:filled + take] = doc[:take]
            filled += take
            if filled < cfg.seq_len + 1:
                out[filled] = cfg.eos_id
                filled += 1
        return out

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        local = cfg.global_batch // self.size
        rows = [self._row(step * cfg.global_batch + self.rank * local + j)
                for j in range(local)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
