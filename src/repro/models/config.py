"""Unified model configuration covering all assigned architecture families.

A model is a stack of (mixer, mlp) residual blocks described by a repeating
``pattern``.  Pattern entries:

  "g"      global causal attention + dense MLP
  "l"      local (sliding-window) attention + dense MLP
  "g:moe"  global attention + MoE MLP
  "l:moe"  local attention + MoE MLP
  "r"      RG-LRU recurrent block (Griffin) + dense MLP
  "m"      Mamba-1 selective-SSM block (no separate MLP)

The stack is ``n_layers`` long: ``n_layers // len(pattern)`` full repeats of
the pattern (scanned for compile speed) plus an explicit remainder.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    o_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    window: int = 0                # sliding window for "l" layers
    causal: bool = True            # False -> encoder (bidirectional, no cache)
    # mlp
    d_ff: int = 0
    gated_mlp: bool = True
    mlp_bias: bool = False
    activation: str = "silu"       # silu | gelu
    # layer pattern
    pattern: Tuple[str, ...] = ("g",)
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert: bool = False
    router: str = "softmax"        # softmax | sigmoid
    capacity_factor: float = 1.25
    # §Perf knob: shard expert FFN dim on fsdp (weights resident — no
    # per-layer FSDP gather; activations all-reduce instead)
    moe_shard_ff: bool = False
    # ssm (mamba) / rglru (griffin)
    d_inner: int = 0
    ssm_state: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    lru_width: int = 0
    # §Perf knobs for the selective-scan path
    ssm_scan_dtype: str = "float32"   # bf16 halves scan HBM traffic
    ssm_chunk: int = 256              # assoc-scan chunk (log-factor levels)
    ssm_impl: str = "assoc"           # assoc | noscan (traffic isolation)
    # §Perf knob for attention: "online" (XLA online-softmax baseline) or
    # "iso" (I/O-preserving linear-attention stand-in: measures the model
    # *minus* the score-block traffic the Pallas flash kernel eliminates)
    attn_impl: str = "online"
    # embeddings / frontends
    tie_embeddings: bool = True
    padded_vocab: int = 0          # 0 -> auto-pad to a multiple of 128
    frontend: str = "none"         # none | audio_stub | vision_stub
    n_image_embeds: int = 0        # vision_stub: patch embeddings per sample
    embed_scale: bool = False      # gemma-style sqrt(d) embedding scaling
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # applicability
    supports_decode: bool = True
    supports_long_context: bool = False
    remat: bool = True

    # ---- derived ----------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    @property
    def v_pad(self) -> int:
        if self.padded_vocab:
            return self.padded_vocab
        return ((self.vocab + 127) // 128) * 128

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers - self.n_units * len(self.pattern)]

    def entry(self, e: str) -> Tuple[str, str]:
        """Split a pattern entry into (mixer_kind, mlp_kind)."""
        mixer, _, tag = e.partition(":")
        if mixer == "m":
            return "mamba", "none"
        if mixer == "r":
            return "rglru", "moe" if tag == "moe" else "dense"
        kind = {"g": "attn_g", "l": "attn_l"}[mixer]
        return kind, ("moe" if tag == "moe" else "dense")

    def validate(self) -> None:
        assert self.n_layers >= len(self.pattern)
        for e in self.pattern:
            self.entry(e)
        if any("moe" in e for e in self.pattern):
            assert self.n_experts > 0 and self.top_k > 0 and self.d_ff_expert > 0
        if any(e.startswith("m") for e in self.pattern):
            assert self.d_inner > 0 and self.ssm_state > 0
        if any(e.startswith("r") for e in self.pattern):
            assert self.lru_width > 0


def reduced(cfg: ModelConfig, **over) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    pat = cfg.pattern
    base = dict(
        name=cfg.name + "-smoke",
        n_layers=max(len(pat), 2 if len(pat) == 1 else len(pat)),
        d_model=64,
        vocab=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=128 if cfg.d_ff else 0,
        pattern=pat,
        window=min(cfg.window, 8) if cfg.window else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        shared_expert=cfg.shared_expert,
        router=cfg.router,
        d_inner=32 if cfg.d_inner else 0,
        ssm_state=min(cfg.ssm_state, 4) if cfg.ssm_state else 0,
        dt_rank=8 if cfg.dt_rank else 0,
        lru_width=32 if cfg.lru_width else 0,
        conv_width=cfg.conv_width,
        qkv_bias=cfg.qkv_bias,
        o_bias=cfg.o_bias,
        qk_norm=cfg.qk_norm,
        gated_mlp=cfg.gated_mlp,
        mlp_bias=cfg.mlp_bias,
        activation=cfg.activation,
        causal=cfg.causal,
        frontend=cfg.frontend,
        n_image_embeds=8 if cfg.n_image_embeds else 0,
        embed_scale=cfg.embed_scale,
        tie_embeddings=cfg.tie_embeddings,
        supports_decode=cfg.supports_decode,
        supports_long_context=cfg.supports_long_context,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
    base.update(over)
    return ModelConfig(**base)
