"""Model engine: scan-over-pattern-units execution of any ModelConfig.

Layout of params:
  {"embed": {...}, "units": {"p0": stacked, "p1": stacked, ...},
   "rem": [block params ...], "final_norm": {...}}
where "p<i>" corresponds to pattern position i, and every leaf under "units"
has a leading n_units axis consumed by lax.scan (fast compiles even for
64-layer models).  Remainder layers (n_layers % len(pattern)) are explicit.

Caches mirror the same structure.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding import Policy, SINGLE


# ---------------------------------------------------------------------------
# init


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    cfg.validate()
    keys = jax.random.split(key, 4)
    units = {}
    for i, entry in enumerate(cfg.pattern):
        def one(u, _i=i, _e=entry):
            return B.block_init(cfg, _e, jax.random.fold_in(keys[0], u * 37 + _i))
        per_unit = [one(u) for u in range(cfg.n_units)]
        units[f"p{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_unit)
    rem = [B.block_init(cfg, e, jax.random.fold_in(keys[1], 1000 + j))
           for j, e in enumerate(cfg.remainder)]
    return {
        "embed": L.embed_init(cfg, keys[2]),
        "units": units,
        "rem": rem,
        "final_norm": L.rmsnorm_init(cfg),
    }


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    units = {f"p{i}": B.block_specs(cfg, e) for i, e in enumerate(cfg.pattern)}
    return {
        "embed": L.embed_specs(cfg),
        "units": units,
        "rem": [B.block_specs(cfg, e) for e in cfg.remainder],
        "final_norm": L.rmsnorm_specs(cfg),
    }


def param_shapes(cfg: ModelConfig):
    """Shape-only params via eval_shape (no allocation) for the dry-run."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


def count_params(cfg: ModelConfig) -> int:
    import math
    shapes = param_shapes(cfg)
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))


# ---------------------------------------------------------------------------
# caches


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               stacked: bool = True):
    """stacked=True: leaves carry a leading n_units axis (scan layout, used
    by prefill outputs).  stacked=False: per-unit list (decode layout — each
    donated leaf is updated in place with no full-stack copies)."""
    rem = [B.block_cache(cfg, e, batch, max_seq) for e in cfg.remainder]
    if stacked:
        units = {}
        for i, entry in enumerate(cfg.pattern):
            one = B.block_cache(cfg, entry, batch, max_seq)
            units[f"p{i}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_units,) + x.shape).copy(),
                one)
        return {"units": units, "rem": rem}
    units_list = [
        {f"p{i}": B.block_cache(cfg, entry, batch, max_seq)
         for i, entry in enumerate(cfg.pattern)}
        for _ in range(cfg.n_units)]
    return {"units_list": units_list, "rem": rem}


def cache_shapes(cfg: ModelConfig, batch: int, max_seq: int,
                 stacked: bool = True):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, stacked))


def unstack_cache(cfg: ModelConfig, cache):
    """Convert a prefill (stacked) cache into the decode (list) layout."""
    if "units_list" in cache:
        return cache
    units_list = [
        jax.tree.map(lambda x: x[i], cache["units"])
        for i in range(cfg.n_units)]
    return {"units_list": units_list, "rem": cache["rem"]}


# ---------------------------------------------------------------------------
# embedding / frontends


def _embed_inputs(cfg: ModelConfig, params, batch: Dict[str, Any],
                  policy: Policy):
    if cfg.frontend == "audio_stub":
        # precomputed frame embeddings straight from the input spec
        h = batch["frames"].astype(cfg.cdtype)
        return policy.constrain(h, policy.batch(None, None))
    h = L.embed_apply(cfg, params["embed"], batch["tokens"], policy)
    if cfg.frontend == "vision_stub" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(h.dtype)
        P_ = img.shape[1]
        h = jnp.concatenate([img, h[:, P_:]], axis=1)
        h = policy.constrain(h, policy.batch(None, None))
    return h


# ---------------------------------------------------------------------------
# forward (train / prefill)


def _seq_res_spec(cfg: ModelConfig, h, policy: Policy, mode: str):
    """Sequence-sharded residual stream (Megatron-SP style): when attention
    is not head-sharded, the (B, S, D) carry between blocks is sharded on
    the tp axis along S — cutting live activation memory by tp_size.  The
    blocks' own constraints re-gather exactly where needed."""
    if mode == "decode" or not policy.enabled:
        return None
    if policy.shard_heads(max(cfg.n_heads, 1), max(cfg.n_kv_heads, 1)):
        return None
    S = h.shape[1]
    if policy.tp is None or S % max(1, policy.tp_size()) != 0:
        return None
    return policy.batch(policy.tp, None)


# parameters kept in fp32 even when compute is bf16 (numerics-sensitive)
_KEEP_F32 = {"lam", "A_log", "dt_bias", "D_skip", "router"}


def _cast_for_compute(cfg: ModelConfig, tree):
    """Cast matrix params to the compute dtype *before* the unit scan so the
    FSDP all-gathers inside the scan move bf16, not fp32 — halving both the
    gather traffic and the gathered-weight working set.  Gradients still
    accumulate in fp32 (astype is linear; its cotangent casts back)."""
    cd = cfg.cdtype
    if cd == jnp.float32:
        return tree

    def one(path, x):
        name = getattr(path[-1], "key", getattr(path[-1], "name", ""))
        if name in _KEEP_F32 or x.ndim < 2 or x.dtype != jnp.float32:
            return x
        return x.astype(cd)
    return jax.tree_util.tree_map_with_path(one, tree)


def _run_stack(cfg: ModelConfig, params, h, policy: Policy, *, mode,
               cache=None, pos=None):
    """Returns (h, new_cache or None)."""
    want_cache = mode in ("prefill", "decode")
    res_spec = _seq_res_spec(cfg, h, policy, mode)
    params = dict(params)
    params["units"] = _cast_for_compute(cfg, params["units"])
    params["rem"] = _cast_for_compute(cfg, params["rem"])

    def one_block(entry, bp, h, c):
        h, nc = B.block_apply(cfg, entry, bp, h, policy, mode=mode,
                              cache=c, pos=pos)
        if res_spec is not None:
            h = policy.constrain(h, res_spec)
        return h, nc

    if cfg.remat and mode == "train":
        # per-block remat: backward recomputes one block at a time, so the
        # live set is a single block's intermediates, not a whole unit's
        one_block = jax.checkpoint(
            one_block, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0,))

    def unit_body(h, pu, cu):
        new_cs = {}
        for i, entry in enumerate(cfg.pattern):
            c = cu[f"p{i}"] if cu is not None else None
            h, nc = one_block(entry, pu[f"p{i}"], h, c)
            new_cs[f"p{i}"] = nc
        return h, (new_cs if want_cache else None)

    unit_list_out = None
    if cfg.n_units > 0:
        if cache is not None and "units_list" in cache:
            # decode layout: unrolled, per-unit donated leaves updated in
            # place (no stacked-cache copies)
            unit_list_out = []
            for i in range(cfg.n_units):
                pu = jax.tree.map(lambda x: x[i], params["units"])
                h, ncs = unit_body(h, pu, cache["units_list"][i])
                unit_list_out.append(ncs)
            unit_caches = None
        elif cache is not None:
            def scan_fn(h, xs):
                pu, cu = xs
                return unit_body(h, pu, cu)
            h, unit_caches = jax.lax.scan(scan_fn, h,
                                          (params["units"], cache["units"]))
        else:
            def scan_fn(h, pu):
                return unit_body(h, pu, None)
            h, unit_caches = jax.lax.scan(scan_fn, h, params["units"])
    else:
        unit_caches = None

    rem_caches = []
    for j, entry in enumerate(cfg.remainder):
        c = cache["rem"][j] if cache is not None else None
        h, nc = B.block_apply(cfg, entry, params["rem"][j], h, policy,
                              mode=mode, cache=c, pos=pos)
        rem_caches.append(nc)

    if not want_cache:
        return h, None
    if unit_list_out is not None:
        return h, {"units_list": unit_list_out, "rem": rem_caches}
    return h, {"units": unit_caches, "rem": rem_caches}


def forward(cfg: ModelConfig, params, batch: Dict[str, Any],
            policy: Policy = SINGLE, mode: str = "train"):
    """Full-sequence forward. Returns logits (B, S, v_pad)."""
    h = _embed_inputs(cfg, params, batch, policy)
    h = policy.constrain(h, policy.batch(None, None))
    h, _ = _run_stack(cfg, params, h, policy, mode="train")
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return L.lm_head(cfg, params["embed"], h, policy)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, Any],
            policy: Policy = SINGLE):
    logits = forward(cfg, params, batch, policy)
    return L.cross_entropy(cfg, logits, batch["labels"], policy)


# ---------------------------------------------------------------------------
# inference


def prefill(cfg: ModelConfig, params, batch: Dict[str, Any],
            policy: Policy = SINGLE):
    """Process the prompt; returns (last_token_logits (B, v_pad), cache)."""
    if not cfg.supports_decode:
        # encoder: "prefill" is a plain forward; no cache
        logits = forward(cfg, params, batch, policy, mode="train")
        return logits[:, -1], None
    h = _embed_inputs(cfg, params, batch, policy)
    h, cache = _run_stack(cfg, params, h, policy, mode="prefill")
    h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], h, policy)
    return logits[:, 0], cache


def decode_step(cfg: ModelConfig, params, cache, token, pos,
                policy: Policy = SINGLE):
    """One decode step. token: (B,) int32; pos: scalar int32 (cache slot &
    rope position of the incoming token). Returns (logits (B, v_pad), cache).
    """
    assert cfg.supports_decode
    h = L.embed_apply(cfg, params["embed"], token[:, None], policy)
    h, new_cache = _run_stack(cfg, params, h, policy, mode="decode", cache=cache,
                              pos=pos)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = L.lm_head(cfg, params["embed"], h, policy)
    return logits[:, 0], new_cache


def greedy_token(cfg: ModelConfig, logits):
    """Argmax over the un-padded vocab."""
    V = cfg.vocab
    iota = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    masked = jnp.where(iota < V, logits.astype(jnp.float32), -jnp.inf)
    return jnp.argmax(masked, axis=-1).astype(jnp.int32)
