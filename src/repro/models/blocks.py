"""Mixer blocks (attention / RG-LRU / Mamba-1) and residual block assembly.

Every mixer implements the same contract:

  init(cfg, key) -> params        specs(cfg) -> logical-spec pytree
  cache(cfg, B)  -> zero state    apply(cfg, p, x, policy, mode, cache, pos)
                                   -> (y, new_cache)

`mode` is "train" | "prefill" | "decode".  Train and prefill process a full
(B, S, D) sequence (prefill additionally emits a filled cache); decode
processes (B, 1, D) against the cache at scalar position `pos`.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import Policy

# ---------------------------------------------------------------------------
# causal depthwise temporal conv (shared by mamba / rglru)


def causal_conv(u, w, b=None):
    """u: (B, S, C), w: (W, C) depthwise causal conv along S."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(pad[:, j:j + u.shape[1], :] * w[j] for j in range(W))
    return y + b if b is not None else y


def conv_step(state, u1, w, b=None):
    """state: (B, W-1, C); u1: (B, 1, C) -> (y1, new_state)."""
    full = jnp.concatenate([state, u1], axis=1)            # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, w)[:, None]
    if b is not None:
        y = y + b
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# chunked associative linear recurrence: h_t = a_t * h_{t-1} + b_t


def _combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return a1 * a2, a2 * b1 + b2


def linear_recurrence(a, b, h0, chunk=256):
    """a, b: (B, S, ...) fp32; h0: (B, ...).  Returns (h_all (B,S,...), h_last).

    Scans over S in chunks; within a chunk uses an associative scan, so peak
    memory is O(B * chunk * state) instead of O(B * S * state)."""
    B, S = a.shape[0], a.shape[1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = jnp.moveaxis(a.reshape((B, nc, chunk) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, nc, chunk) + b.shape[2:]), 1, 0)

    def step(h, xs):
        aj, bj = xs                                         # (B, chunk, ...)
        a_sc, b_sc = jax.lax.associative_scan(_combine, (aj, bj), axis=1)
        hj = a_sc * h[:, None] + b_sc
        return hj[:, -1], hj

    h_last, hs = jax.lax.scan(step, h0, (ac, bc))
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, nc * chunk) + a.shape[2:])
    return hs[:, :S], h_last


# ---------------------------------------------------------------------------
# RG-LRU (Griffin / RecurrentGemma recurrent block)

_RG_C = 8.0


def rglru_init(cfg, key):
    D, R, W = cfg.d_model, cfg.lru_width, cfg.conv_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[4], (R,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RG_C))  # softplus^-1(-log(u)/c)
    return {
        "w_x": L._dense_init(ks[0], (D, R), cfg.pdtype),
        "w_g": L._dense_init(ks[1], (D, R), cfg.pdtype),
        "conv_w": L._dense_init(ks[2], (W, R), cfg.pdtype, fan_in=W),
        "conv_b": jnp.zeros((R,), cfg.pdtype),
        "w_a": L._dense_init(ks[3], (R, R), cfg.pdtype),
        "b_a": jnp.zeros((R,), cfg.pdtype),
        "w_i": L._dense_init(ks[5], (R, R), cfg.pdtype),
        "b_i": jnp.zeros((R,), cfg.pdtype),
        "lam": lam,
        "w_out": L._dense_init(jax.random.fold_in(key, 9), (R, D),
                               cfg.pdtype, fan_in=R),
    }


def rglru_specs(cfg):
    return {"w_x": ("fsdp", "tp"), "w_g": ("fsdp", "tp"),
            "conv_w": (None, "tp"), "conv_b": ("tp",),
            "w_a": ("fsdp", "tp"), "b_a": ("tp",),
            "w_i": ("fsdp", "tp"), "b_i": ("tp",),
            "lam": ("tp",), "w_out": ("tp", "fsdp")}


def rglru_cache(cfg, batch):
    R, W = cfg.lru_width, cfg.conv_width
    return {"h": jnp.zeros((batch, R), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, R), jnp.float32)}


def rglru_apply(cfg, p, x, policy: Policy, *, mode, cache=None, pos=None):
    cd = cfg.cdtype
    B, S, D = x.shape
    xc = x.astype(cd)
    if mode == "decode" and policy.enabled and policy.resident_decode:
        from jax.sharding import PartitionSpec as P
        xc = policy.constrain(xc, P(None, None, policy.fsdp))
    u = jnp.einsum("bsd,dr->bsr", xc, p["w_x"].astype(cd))
    g = jax.nn.gelu(jnp.einsum("bsd,dr->bsr", xc, p["w_g"].astype(cd)))
    u = policy.constrain(u, policy.batch(None, policy.tp))

    new_cache = cache
    if mode == "decode":
        conv_out, conv_state = conv_step(cache["conv"],
                                         u.astype(jnp.float32),
                                         p["conv_w"].astype(jnp.float32),
                                         p["conv_b"].astype(jnp.float32))
        u32 = conv_out[:, None] if conv_out.ndim == 2 else conv_out
    else:
        u32 = causal_conv(u.astype(jnp.float32), p["conv_w"].astype(jnp.float32),
                          p["conv_b"].astype(jnp.float32))
        # conv state holds the last W-1 *pre-conv* inputs
        conv_state = (u.astype(jnp.float32)[:, -(cfg.conv_width - 1):, :]
                      if mode == "prefill" else None)

    r = jax.nn.sigmoid(u32 @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(u32 @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_RG_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * (i * u32)

    if mode == "decode":
        h = a[:, 0] * cache["h"] + b[:, 0]
        new_cache = {"h": h, "conv": conv_state}
        hs = h[:, None]
    else:
        h0 = jnp.zeros((B, cfg.lru_width), jnp.float32)
        hs, h_last = linear_recurrence(a, b, h0)
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": conv_state}
    hs = policy.constrain(hs, policy.batch(None, policy.tp))
    y = jnp.einsum("bsr,rd->bsd", (hs.astype(cd) * g), p["w_out"].astype(cd))
    return y.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM


def mamba_init(cfg, key):
    D, Di, N, R, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.dt_rank, cfg.conv_width)
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (Di, 1))
    return {
        "in_proj": L._dense_init(ks[0], (D, 2 * Di), cfg.pdtype),
        "conv_w": L._dense_init(ks[1], (W, Di), cfg.pdtype, fan_in=W),
        "conv_b": jnp.zeros((Di,), cfg.pdtype),
        "x_proj": L._dense_init(ks[2], (Di, R + 2 * N), cfg.pdtype),
        "dt_proj": L._dense_init(ks[3], (R, Di), cfg.pdtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (Di,), jnp.float32,
                                        1e-3, 1e-1), 1e-4, None))),
        "A_log": jnp.log(A),
        "D_skip": jnp.ones((Di,), jnp.float32),
        "out_proj": L._dense_init(ks[5], (Di, D), cfg.pdtype, fan_in=Di),
    }


def mamba_specs(cfg):
    return {"in_proj": ("fsdp", "tp"), "conv_w": (None, "tp"),
            "conv_b": ("tp",), "x_proj": ("tp", "fsdp"),
            "dt_proj": ("fsdp", "tp"), "dt_bias": ("tp",),
            "A_log": ("tp", None), "D_skip": ("tp",),
            "out_proj": ("tp", "fsdp")}


def mamba_cache(cfg, batch):
    Di, N, W = cfg.d_inner, cfg.ssm_state, cfg.conv_width
    return {"h": jnp.zeros((batch, Di, N), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, Di), jnp.float32)}


def mamba_apply(cfg, p, x, policy: Policy, *, mode, cache=None, pos=None):
    cd = cfg.cdtype
    B, S, D = x.shape
    Di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xc = x.astype(cd)
    if mode == "decode" and policy.enabled and policy.resident_decode:
        from jax.sharding import PartitionSpec as P
        xc = policy.constrain(xc, P(None, None, policy.fsdp))
    xz = jnp.einsum("bsd,de->bse", xc, p["in_proj"].astype(cd))
    u, z = jnp.split(xz, 2, axis=-1)
    u = policy.constrain(u, policy.batch(None, policy.tp))

    new_cache = cache
    if mode == "decode":
        u1, conv_state = conv_step(cache["conv"], u.astype(jnp.float32),
                                   p["conv_w"].astype(jnp.float32),
                                   p["conv_b"].astype(jnp.float32))
        u32 = jax.nn.silu(u1[:, None] if u1.ndim == 2 else u1)
    else:
        u32 = jax.nn.silu(causal_conv(u.astype(jnp.float32),
                                      p["conv_w"].astype(jnp.float32),
                                      p["conv_b"].astype(jnp.float32)))
        # conv state holds the last W-1 *pre-conv* inputs
        conv_state = (u.astype(jnp.float32)[:, -(cfg.conv_width - 1):, :]
                      if mode == "prefill" else None)

    dbc = u32 @ p["x_proj"].astype(jnp.float32)
    dt_r, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ p["dt_proj"].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                   # (Di, N)
    decay = jnp.exp(dt[..., None] * A)                         # (B,S,Di,N)
    inp = (dt * u32)[..., None] * Bm[..., None, :]             # (B,S,Di,N)

    if mode == "decode":
        h = decay[:, 0] * cache["h"] + inp[:, 0]
        new_cache = {"h": h, "conv": conv_state}
        hs = h[:, None]
    elif cfg.ssm_impl == "noscan":
        # measurement-only variant (§Perf traffic isolation): identity
        # recurrence with identical tensor I/O — the dry-run diff against
        # "assoc" attributes HBM traffic to the scan itself
        hs, h_last = inp, inp[:, -1]
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": conv_state}
    else:
        sdt = jnp.bfloat16 if cfg.ssm_scan_dtype == "bfloat16" \
            else jnp.float32
        h0 = jnp.zeros((B, Di, N), sdt)
        hs, h_last = linear_recurrence(decay.astype(sdt), inp.astype(sdt),
                                       h0, chunk=min(cfg.ssm_chunk,
                                                     max(16, S)))
        hs, h_last = hs.astype(jnp.float32), h_last.astype(jnp.float32)
        if mode == "prefill":
            new_cache = {"h": h_last, "conv": conv_state}

    y = jnp.einsum("bsdn,bsn->bsd", hs, Cm) + p["D_skip"] * u32
    y = policy.constrain(y.astype(cd), policy.batch(None, policy.tp))
    out = jnp.einsum("bse,ed->bsd", y * jax.nn.silu(z), p["out_proj"].astype(cd))
    return out.astype(x.dtype), new_cache


# ---------------------------------------------------------------------------
# Block assembly: pre-norm residual (mixer, mlp) pairs


MIXERS = {
    "attn_g": (L.attn_init, L.attn_specs),
    "attn_l": (L.attn_init, L.attn_specs),
    "rglru": (rglru_init, rglru_specs),
    "mamba": (mamba_init, mamba_specs),
}


def block_init(cfg, entry, key):
    mixer, mlp = cfg.entry(entry)
    ks = jax.random.split(key, 2)
    init, _ = MIXERS[mixer]
    p = {"norm1": L.rmsnorm_init(cfg), "mixer": init(cfg, ks[0])}
    if mlp == "dense":
        p["norm2"] = L.rmsnorm_init(cfg)
        d_ff = cfg.d_ff
        p["mlp"] = L.mlp_init(cfg, ks[1], d_ff=d_ff)
    elif mlp == "moe":
        p["norm2"] = L.rmsnorm_init(cfg)
        p["mlp"] = L.moe_init(cfg, ks[1])
    return p


def block_specs(cfg, entry):
    mixer, mlp = cfg.entry(entry)
    _, specs = MIXERS[mixer]
    s = {"norm1": L.rmsnorm_specs(cfg), "mixer": specs(cfg)}
    if mlp == "dense":
        s["norm2"] = L.rmsnorm_specs(cfg)
        s["mlp"] = L.mlp_specs(cfg)
    elif mlp == "moe":
        s["norm2"] = L.rmsnorm_specs(cfg)
        s["mlp"] = L.moe_specs(cfg)
    return s


def block_cache(cfg, entry, batch, max_seq):
    """Zero cache for one block.  Local-attn caches are window-sized."""
    mixer, _ = cfg.entry(entry)
    if mixer == "attn_g":
        return L.attn_cache_shape(cfg, batch, max_seq)
    if mixer == "attn_l":
        return L.attn_cache_shape(cfg, batch, min(max_seq, cfg.window))
    if mixer == "rglru":
        return rglru_cache(cfg, batch)
    if mixer == "mamba":
        return mamba_cache(cfg, batch)
    raise ValueError(mixer)


def block_apply(cfg, entry, p, x, policy: Policy, *, mode, cache=None,
                pos=None):
    mixer, mlp = cfg.entry(entry)
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if mixer in ("attn_g", "attn_l"):
        window = cfg.window if mixer == "attn_l" else 0
        y, new_cache = L.attn_apply(cfg, p["mixer"], h, policy, mode=mode,
                                    window=window, cache=cache, pos=pos)
    elif mixer == "rglru":
        y, new_cache = rglru_apply(cfg, p["mixer"], h, policy, mode=mode,
                                   cache=cache, pos=pos)
    else:
        y, new_cache = mamba_apply(cfg, p["mixer"], h, policy, mode=mode,
                                   cache=cache, pos=pos)
    x = x + y
    if mlp != "none":
        h2 = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
        if mlp == "dense":
            x = x + L.mlp_apply(cfg, p["mlp"], h2, policy,
                                decode=(mode == "decode"))
        else:
            x = x + L.moe_apply(cfg, p["mlp"], h2, policy,
                                decode=(mode == "decode"))
    return x, new_cache
