"""Core layers: RMSNorm, RoPE, GQA attention (dense + online-softmax paths),
dense/gated MLP, and sort-based MoE MLP with capacity dropping.

All functions are pure; parameters are dicts of arrays.  Logical sharding
specs live beside each init in ``*_specs`` (trailing-dim tuples consumed by
``repro.sharding.logical_to_spec``).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import Policy

# ---------------------------------------------------------------------------
# initializers


def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm


def rmsnorm_init(cfg, dim=None):
    return {"scale": jnp.ones((dim or cfg.d_model,), cfg.pdtype)}


def rmsnorm_specs(cfg, dim=None):
    return {"scale": ()}


def rmsnorm(p, x, eps):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE


def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, optional qk-norm)


def attn_init(cfg, key):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (D, H * hd), cfg.pdtype),
        "wk": _dense_init(ks[1], (D, KV * hd), cfg.pdtype),
        "wv": _dense_init(ks[2], (D, KV * hd), cfg.pdtype),
        "wo": _dense_init(ks[3], (H * hd, D), cfg.pdtype, fan_in=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.pdtype)
    if cfg.o_bias:
        p["bo"] = jnp.zeros((D,), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    return p


def attn_specs(cfg):
    s = {"wq": ("fsdp", "tp"), "wk": ("fsdp", "tp"), "wv": ("fsdp", "tp"),
         "wo": ("tp", "fsdp")}
    if cfg.qkv_bias:
        s.update({"bq": ("tp",), "bk": ("tp",), "bv": ("tp",)})
    if cfg.o_bias:
        s["bo"] = ()
    if cfg.qk_norm:
        s.update({"q_norm": (), "k_norm": ()})
    return s


def _qk_normalize(q, k, p, eps):
    def nrm(x, scale):
        x32 = x.astype(jnp.float32)
        y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
        return (y * scale.astype(jnp.float32)).astype(x.dtype)
    return nrm(q, p["q_norm"]), nrm(k, p["k_norm"])


def _mask(q_pos, k_pos, window):
    """(..., S, T) boolean validity mask. q_pos/k_pos broadcastable int32."""
    m = (k_pos[..., None, :] <= q_pos[..., :, None]) & (k_pos[..., None, :] >= 0)
    if window:
        m &= (q_pos[..., :, None] - k_pos[..., None, :]) < window
    return m


def _attn_dense(q, k, v, q_pos, k_pos, window, causal=True):
    """q: (B,S,KV,G,hd)  k,v: (B,T,KV,hd).  Returns (B,S,KV,G,hd)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bskgh,btkh->bkgst", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        valid = _mask(q_pos, k_pos, window)          # (B?,S,T) or (S,T)
        valid = valid[..., None, None, :, :] if valid.ndim == 3 else valid
        s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def _attn_online(q, k, v, q_pos, k_pos, window, causal=True, blk=1024):
    """Online-softmax (flash-style) attention scanned over KV blocks.

    Never materialises the full (S, T) score matrix: peak live memory is
    (B, KV, G, S, blk).  This is the XLA fallback for the Pallas kernel.
    """
    B, S, KV, G, hd = q.shape
    T = k.shape[1]
    nblk = (T + blk - 1) // blk
    Tp = nblk * blk
    if Tp != T:
        pad = [(0, 0), (0, Tp - T), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        k_pos = jnp.pad(k_pos, ((0, Tp - T),), constant_values=jnp.iinfo(jnp.int32).max)
    scale = 1.0 / math.sqrt(hd)
    kb = k.reshape(B, nblk, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nblk, blk, KV, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nblk, blk)

    def step(carry, xs):
        m, d, acc = carry
        kj, vj, pj = xs
        s = jnp.einsum("bskgh,btkh->bkgst", q, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            valid = _mask(q_pos, pj, window)  # (S, blk)
            s = jnp.where(valid[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        d = d * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, d, acc), None

    m0 = jnp.full((B, KV, G, S), -jnp.inf, jnp.float32)
    d0 = jnp.zeros((B, KV, G, S), jnp.float32)
    a0 = jnp.zeros((B, KV, G, S, hd), jnp.float32)
    (m, d, acc), _ = jax.lax.scan(step, (m0, d0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(d, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # (B,S,KV,G,hd)


def attn_apply(cfg, p, x, policy: Policy, *, mode, window,
               cache=None, pos=None):
    """Full attention layer.  mode: train|prefill|decode.

    cache (decode / prefill output): {"k","v"}: (B, S_max, KV, hd).
    pos: scalar int32 decode position (k/v written at `pos`).
    Returns (y, new_cache).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    cd = cfg.cdtype
    xq = x.astype(cd)
    resident = (mode == "decode" and policy.enabled
                and policy.resident_decode)
    if resident:
        from jax.sharding import PartitionSpec as P
        xq = policy.constrain(xq, P(None, None, policy.fsdp))

    def proj(w, b=None):
        y = jnp.einsum("bsd,df->bsf", xq, p[w].astype(cd))
        if b and b in p:
            y = y + p[b].astype(cd)
        return y

    q = proj("wq", "bq").reshape(B, S, H, hd)
    k = proj("wk", "bk").reshape(B, S, KV, hd)
    v = proj("wv", "bv").reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q, k = _qk_normalize(q, k, p, cfg.norm_eps)

    if mode == "decode":
        if jnp.ndim(pos) == 1:          # per-sequence positions (serving)
            q_pos = pos[:, None].astype(jnp.int32)          # (B, 1)
        else:
            q_pos = pos + jnp.zeros((1,), jnp.int32)        # (1,)
        k_pos_new = q_pos
    else:
        q_pos = jnp.arange(S, dtype=jnp.int32)
        k_pos_new = q_pos
    q = rope(q, q_pos, cfg.rope_theta)
    k = rope(k, k_pos_new, cfg.rope_theta)

    # -- sharding of attention intermediates --------------------------------
    head_sharded = policy.shard_heads(H, KV)
    if head_sharded:
        q = policy.constrain(q, policy.batch(None, policy.tp, None))
        k = policy.constrain(k, policy.batch(None, policy.tp, None))
        v = policy.constrain(v, policy.batch(None, policy.tp, None))
    elif mode != "decode":
        # sequence-parallel queries, replicated kv
        q = policy.constrain(q, policy.batch(policy.tp, None, None))
        k = policy.constrain(k, policy.batch(None, None, None))
        v = policy.constrain(v, policy.batch(None, None, None))

    new_cache = cache
    if mode == "decode":
        assert cache is not None
        W = cache["k"].shape[1]
        spec = policy.cache_spec(B, hd)
        vec_pos = jnp.ndim(pos) == 1
        if window and W == min(window, W):
            # rolling window cache: slots always hold the last W positions.
            # Sharded on batch only — a seq-sharded rolling shift would
            # cross shard boundaries every step (measured: the dominant
            # decode collective for local-attention archs, §Perf C3).
            spec = (jax.sharding.PartitionSpec(policy.dp, None, None, None)
                    if policy.enabled and B % max(1, policy.dp_size()) == 0
                    else policy.cache_spec(B, hd))
            k_all = jnp.concatenate([cache["k"][:, 1:],
                                     k.astype(cache["k"].dtype)], axis=1)
            v_all = jnp.concatenate([cache["v"][:, 1:],
                                     v.astype(cache["v"].dtype)], axis=1)
            rel = jnp.arange(W, dtype=jnp.int32) - (W - 1)
            k_pos = (pos[:, None] + rel[None, :]) if vec_pos else pos + rel
        elif vec_pos:
            # per-sequence write positions (serving engine): scatter rows
            bidx = jnp.arange(B)
            k_all = cache["k"].at[bidx, pos].set(
                k[:, 0].astype(cache["k"].dtype))
            v_all = cache["v"].at[bidx, pos].set(
                v[:, 0].astype(cache["v"].dtype))
            k_pos = jnp.broadcast_to(
                jnp.arange(k_all.shape[1], dtype=jnp.int32),
                (B, k_all.shape[1]))
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            k_pos = jnp.arange(k_all.shape[1], dtype=jnp.int32)
        k_all = policy.constrain(k_all, spec)
        v_all = policy.constrain(v_all, spec)
        new_cache = {"k": k_all, "v": v_all}
        qg = q.reshape(B, S, KV, G, hd)
        o = _attn_dense(qg, k_all.astype(cd), v_all.astype(cd),
                        q_pos, k_pos, window)
    else:
        qg = q.reshape(B, S, KV, G, hd)
        if mode == "prefill":
            kc, vc = k.astype(cfg.cdtype), v.astype(cfg.cdtype)
            if window and S >= window:
                kc, vc = kc[:, -window:], vc[:, -window:]
            spec = policy.cache_spec(B, hd)
            new_cache = {"k": policy.constrain(kc, spec),
                         "v": policy.constrain(vc, spec)}
        if cfg.attn_impl == "iso":
            # measurement-only (§Perf): same I/O shapes, no (S,T) score
            # materialization — isolates non-attention traffic; combined
            # with the flash-kernel traffic model in EXPERIMENTS.md §Perf
            kv_ = jnp.einsum("btkh,btkg->bkhg", k, v,
                             preferred_element_type=jnp.float32)
            o = jnp.einsum("bskgh,bkhj->bskgj", qg,
                           kv_.astype(cd)) / max(1, k.shape[1])
        elif cfg.causal:
            fn = _attn_dense if S <= 2048 else _attn_online
            o = fn(qg, k, v, q_pos, k_pos_new, window)
        else:  # bidirectional encoder
            if S <= 2048:
                o = _attn_dense(qg, k, v, q_pos, k_pos_new, 0, causal=False)
            else:
                o = _attn_online(qg, k, v, q_pos, k_pos_new, 0, causal=False)

    o = o.reshape(B, S, H * hd)
    if resident:
        from jax.sharding import PartitionSpec as P
        o = policy.constrain(o, P(None, None,
                                  policy.maybe(policy.tp, H * hd)))
    elif head_sharded:
        o = policy.constrain(o, policy.batch(None, policy.tp))
    y = jnp.einsum("bsf,fd->bsd", o, p["wo"].astype(cd))
    if "bo" in p:
        y = y + p["bo"].astype(cd)
    return y.astype(x.dtype), new_cache


def attn_cache_shape(cfg, batch, max_seq):
    KV, hd = cfg.n_kv_heads, cfg.hd
    z = jnp.zeros  # caller may eval_shape this
    return {"k": z((batch, max_seq, KV, hd), cfg.cdtype),
            "v": z((batch, max_seq, KV, hd), cfg.cdtype)}


# ---------------------------------------------------------------------------
# Dense MLP (gated or plain)


def mlp_init(cfg, key, d_ff=None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_out": _dense_init(ks[2], (F, D), cfg.pdtype, fan_in=F)}
    p["w_in"] = _dense_init(ks[0], (D, F), cfg.pdtype)
    if cfg.gated_mlp:
        p["w_gate"] = _dense_init(ks[1], (D, F), cfg.pdtype)
    if cfg.mlp_bias:
        p["b_in"] = jnp.zeros((F,), cfg.pdtype)
        p["b_out"] = jnp.zeros((D,), cfg.pdtype)
    return p


def mlp_specs(cfg):
    s = {"w_in": ("fsdp", "tp"), "w_out": ("tp", "fsdp")}
    if cfg.gated_mlp:
        s["w_gate"] = ("fsdp", "tp")
    if cfg.mlp_bias:
        s.update({"b_in": ("tp",), "b_out": ()})
    return s


def _act(name):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def mlp_apply(cfg, p, x, policy: Policy, decode: bool = False):
    from jax.sharding import PartitionSpec as P
    cd = cfg.cdtype
    xc = x.astype(cd)
    if decode and policy.enabled and policy.resident_decode:
        # slice D over fsdp: the einsum partial-sums against the resident
        # weight shard; no weight all-gather per decode step
        xc = policy.constrain(xc, P(None, None, policy.fsdp))
    h = jnp.einsum("bsd,df->bsf", xc, p["w_in"].astype(cd))
    if "b_in" in p:
        h = h + p["b_in"].astype(cd)
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", xc, p["w_gate"].astype(cd))
        h = _act(cfg.activation)(g) * h
    else:
        h = _act(cfg.activation)(h)
    if decode and policy.enabled and policy.resident_decode:
        h = policy.constrain(h, P(None, None, policy.tp))
    else:
        h = policy.constrain(h, policy.batch(None, policy.tp))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_out"].astype(cd))
    if "b_out" in p:
        y = y + p["b_out"].astype(cd)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE MLP — sort-based dispatch with capacity dropping (GShard-style),
# experts sharded on the tp axis.  This is exactly Beehive's flow-affine
# scale-out dispatch: tokens are "flows", experts are replicated stateful
# tiles, and the capacity limit is the paper's per-tile queue.


def moe_init(cfg, key):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, E), jnp.float32),
        "w_in": _dense_init(ks[1], (E, D, F), cfg.pdtype, fan_in=D),
        "w_gate": _dense_init(ks[2], (E, D, F), cfg.pdtype, fan_in=D),
        "w_out": _dense_init(ks[3], (E, F, D), cfg.pdtype, fan_in=F),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_init(cfg, ks[4], d_ff=cfg.d_ff_expert)
    return p


def moe_specs(cfg):
    if cfg.moe_shard_ff:
        # resident experts: FFN dim sharded on fsdp; never gathered
        s = {
            "router": (),
            "w_in": ("tp", None, "fsdp"),
            "w_gate": ("tp", None, "fsdp"),
            "w_out": ("tp", "fsdp", None),
        }
    else:
        s = {
            "router": (),
            "w_in": ("tp", "fsdp", None),
            "w_gate": ("tp", "fsdp", None),
            "w_out": ("tp", None, "fsdp"),
        }
    if cfg.shared_expert:
        s["shared"] = mlp_specs(cfg)
    return s


def moe_capacity(cfg, n_tokens):
    c = int(cfg.capacity_factor * n_tokens * cfg.top_k / cfg.n_experts)
    return max(128, ((c + 127) // 128) * 128)


def moe_apply(cfg, p, x, policy: Policy, decode: bool = False):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = moe_capacity(cfg, T)
    cd = cfg.cdtype

    xt = x.reshape(T, D)
    xt = policy.constrain(xt, P_tokens(policy))
    logits = (xt.astype(jnp.float32) @ p["router"])            # (T, E)
    if cfg.router == "sigmoid":
        gates = jax.nn.sigmoid(logits)
        gate_w, eidx = jax.lax.top_k(gates, K)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, eidx = jax.lax.top_k(probs, K)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)                                   # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_w.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts                        # exclusive
    rank = jnp.arange(T * K, dtype=jnp.int32) - starts[se]
    slot = jnp.where(rank < C, se * C + rank, E * C)            # drop overflow
    # token index per (expert, capacity) slot; E*C -> sentinel row
    token_of = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(st)[:-1]
    gate_of = jnp.zeros((E * C + 1,), jnp.float32).at[slot].set(sg)[:-1]

    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], 0)
    # expert dim on tp, capacity dim on dp: each device computes its experts'
    # share of the capacity (this is Beehive's flow-affine dispatch, with
    # per-tile queue depth C/dp_size)
    from jax.sharding import PartitionSpec as P
    if decode and policy.enabled:
        # decode has few tokens: keep weights resident (no FSDP gather) by
        # slicing the contraction dim on the fsdp axis; XLA partial-sums and
        # all-reduces the small (E, C, F) activations instead
        ec_spec = P(policy.tp, None, policy.fsdp)
        h_spec = ye_spec = None
    elif cfg.moe_shard_ff and policy.enabled:
        # resident experts (§Perf): full capacity per device, FFN dim
        # sharded on fsdp — trades the per-layer weight all-gather for a
        # (E, C, D) activation all-reduce
        ec_spec = P(policy.tp, None, None)
        h_spec = P(policy.tp, None, policy.fsdp)
        ye_spec = P(policy.tp, None, None)
    else:
        ec_spec = (P(policy.tp, policy.dp, None) if policy.enabled else P())
        h_spec = ye_spec = ec_spec
    xe = xt_pad[token_of].reshape(E, C, D).astype(cd)           # (E, C, D)
    xe = policy.constrain(xe, ec_spec)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"].astype(cd))
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cd))
    h = _act(cfg.activation)(g) * h
    if h_spec is not None:
        h = policy.constrain(h, h_spec)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(cd))
    if ye_spec is not None:
        ye = policy.constrain(ye, ye_spec)
    ye = ye * gate_of.reshape(E, C, 1).astype(cd)

    out = jnp.zeros((T + 1, D), jnp.float32)
    out = out.at[token_of.reshape(-1)].add(ye.reshape(E * C, D).astype(jnp.float32))
    out = out[:T]
    out = policy.constrain(out, P_tokens(policy))
    y = out.reshape(B, S, D).astype(x.dtype)
    if cfg.shared_expert:
        y = y + mlp_apply(cfg, p["shared"], x, policy)
    return y


def P_tokens(policy: Policy):
    from jax.sharding import PartitionSpec as P
    return P(policy.dp if policy.dp else None, None)


# ---------------------------------------------------------------------------
# Embedding / LM head


def embed_init(cfg, key):
    p = {"table": _dense_init(key, (cfg.v_pad, cfg.d_model), cfg.pdtype,
                              fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(jax.random.fold_in(key, 1),
                                (cfg.d_model, cfg.v_pad), cfg.pdtype)
    return p


def embed_specs(cfg):
    s = {"table": ("tp", "fsdp")}
    if not cfg.tie_embeddings:
        s["head"] = ("fsdp", "tp")
    return s


def embed_apply(cfg, p, tokens, policy: Policy):
    # one-hot free gather; table vocab-sharded on tp => XLA partitions gather
    h = jnp.take(p["table"], tokens, axis=0).astype(cfg.cdtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype)
    return policy.constrain(h, policy.batch(None, None))


def lm_head(cfg, p, h, policy: Policy):
    cd = cfg.cdtype
    w = p["table"].astype(cd).T if cfg.tie_embeddings else p["head"].astype(cd)
    logits = jnp.einsum("bsd,dv->bsv", h.astype(cd), w)
    return policy.constrain(logits, policy.batch(None, policy.tp))


def cross_entropy(cfg, logits, labels, policy: Policy):
    """Next-token CE over a vocab-sharded (padded) logits tensor.

    Uses select+reduce (fusable) instead of materialising a one-hot, and
    masks out the padded vocab tail.  labels < 0 are ignored.
    """
    V = logits.shape[-1]
    l32 = logits.astype(jnp.float32)
    iota = jnp.arange(V, dtype=jnp.int32)
    if cfg.v_pad != cfg.vocab:
        l32 = jnp.where(iota < cfg.vocab, l32, -1e30)
    lse = jax.nn.logsumexp(l32, axis=-1)
    picked = jnp.sum(jnp.where(iota == labels[..., None], l32, 0.0), axis=-1)
    nll = lse - picked
    valid = labels >= 0
    return jnp.sum(jnp.where(valid, nll, 0.0)) / jnp.maximum(valid.sum(), 1)
