"""Per-port token-bucket rate limiting, applied at the dispatch tile.

A small fixed-capacity table (same shape discipline as the routing CAMs:
runtime arrays, rewritable by the control plane) maps an L4 destination
port to a token bucket.  ``apply`` runs once per batch inside the
``udp_rx`` tile: buckets refill by ``rate`` tokens (packets) per batch up
to ``burst``, and packets beyond a port's available tokens are dropped in
arrival order — the drop shows up in the tile's telemetry counters like
any other parse failure.  Ports with no entry are unlimited.

The management plane's ``RATE_SET`` command writes slots live (and
``MgmtConsole.set_rate`` / ``clear_rate`` drive it in-band); a cleared
slot has port -1 and matches nothing.
"""
from __future__ import annotations

import jax.numpy as jnp

I32 = jnp.int32

SLOTS = 8


def init(slots: int = SLOTS):
    return {
        "ports": jnp.full((slots,), -1, I32),
        "rate": jnp.zeros((slots,), I32),     # tokens (packets) per batch
        "burst": jnp.zeros((slots,), I32),    # bucket capacity
        "tokens": jnp.zeros((slots,), I32),
    }


def set_slot(rt, slot, port, rate, burst=None):
    """Install (or rewrite) one bucket; the bucket starts full."""
    burst = rate if burst is None else burst
    rt = dict(rt)
    rt["ports"] = rt["ports"].at[slot].set(jnp.asarray(port, I32))
    rt["rate"] = rt["rate"].at[slot].set(jnp.asarray(rate, I32))
    rt["burst"] = rt["burst"].at[slot].set(jnp.asarray(burst, I32))
    rt["tokens"] = rt["tokens"].at[slot].set(jnp.asarray(burst, I32))
    return rt


def clear_slot(rt, slot):
    return set_slot(rt, slot, -1, 0, 0)


def apply(rt, dst_port, arrived):
    """One batch step.  dst_port: (B,) uint/int, arrived: (B,) bool.
    Returns (rt', ok) — ok[b] False means packet b exceeded its port's
    bucket and must be dropped."""
    tokens = jnp.minimum(rt["tokens"] + rt["rate"], rt["burst"])
    port = dst_port.astype(I32)
    live = rt["ports"] >= 0
    match = (port[:, None] == rt["ports"][None, :]) & live[None, :] \
        & arrived[:, None]                                   # (B, S)
    cum = jnp.cumsum(match.astype(I32), axis=0)              # arrival order
    allowed = cum <= tokens[None, :]
    ok = (~match | allowed).all(axis=1)
    consumed = jnp.minimum(match.sum(axis=0), tokens)
    rt = dict(rt)
    rt["tokens"] = tokens - consumed
    return rt, ok
