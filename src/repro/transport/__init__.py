"""Loss-tolerant transport subsystem.

The paper's TCP engine ships without congestion control (§4.4, a stated
prototype limitation).  This package supplies the missing pieces as the
same kind of state the engine already uses — fixed-shape per-connection
arrays, inspectable by the management plane and serializable for live
migration:

  * :mod:`repro.transport.cc` — congestion-control engine: SRTT/RTTVAR
    estimation with adaptive RTO + exponential backoff, NewReno
    slow-start / congestion-avoidance / fast-recovery, and a DCTCP-style
    ECN policy (per-window alpha), selected per stack by a *tile
    parameter*, never by forking the engine.
  * :mod:`repro.transport.rate` — per-port token-bucket rate limiting
    applied at the UDP dispatch tile, settable in-band via the
    management plane's ``RATE_SET`` command.

The deterministic network-emulation harness that exercises all of this
under loss / delay / reordering lives in :mod:`repro.netem`.
"""
from repro.transport import cc, rate  # noqa: F401
