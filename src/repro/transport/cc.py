"""Congestion-control engine for the TCP connection table.

State is fixed-shape per-connection arrays nested under ``conn["cc"]`` —
the same representation the engine uses for everything else, so a
connection's CC state migrates with it (``tcp.serialize_conn``) and the
management plane can inspect or rewrite any field.  The policy is a
scalar, selected by a topology *tile parameter* (``cc_policy`` on the
``tcp_rx`` tile); when no policy is configured the engine carries no CC
state at all and behaves bit-identically to the paper's prototype.

Implemented:

  * RTT estimation (RFC 6298 integer arithmetic: ``srtt`` scaled by 8,
    ``rttvar`` by 4, one outstanding sample, Karn's rule on
    retransmission) driving an adaptive per-connection RTO with
    exponential backoff on timer expiry.
  * NewReno (RFC 5681/6582): slow start, congestion avoidance, fast
    recovery entered on the 3rd dup-ACK with ``recover = snd_max``,
    partial ACKs keep retransmitting, full ACKs deflate to ``ssthresh``.
  * DCTCP-style ECN (RFC 8257 shape): per-window mark fraction smoothed
    into ``alpha`` (g = 1/16, alpha scaled by 2^10), one
    ``cwnd -= cwnd * alpha / 2`` cut per marked window.  Under the
    classic policy an ECE echo instead halves cwnd once per window
    (RFC 3168).

Time is the engine's tick counter (``tcp.tick`` advances ``cc["now"]``),
mirroring the paper's cycle-count telemetry timestamps.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import telemetry

I32 = jnp.int32
U32 = jnp.uint32

NEWRENO, DCTCP = 0, 1
POLICIES = {"newreno": NEWRENO, "dctcp": DCTCP}
POLICY_NAMES = {v: k for k, v in POLICIES.items()}

IW_SEGS = 10            # initial window (RFC 6928)
CWND_MAX = 1 << 20      # keeps the alpha fixed-point products in int32
RTO_INIT = 8            # ticks (matches the seed engine's fixed timeout)
RTO_MIN, RTO_MAX = 2, 64
ALPHA_SHIFT = 10        # alpha fixed point: 1.0 == 1 << 10
ALPHA_G_SHIFT = 4       # DCTCP g = 1/16

# per-connection arrays, in serialization order (migration blob layout)
PER_CONN = ("cwnd", "ssthresh", "srtt", "rttvar", "rto", "in_rec",
            "recover", "rtt_seq", "rtt_ts", "rtt_pending", "ecn_end",
            "ecn_acked", "ecn_marked", "alpha", "ece_cut",
            "retx_fast", "retx_timer", "marks")


def _seq_lt(a, b):
    """Wrap-safe sequence-space a < b on uint32."""
    return ((a.astype(U32) - b.astype(U32)) >> 31) != 0


def init(max_conns: int, mss: int = 1460, policy="newreno"):
    pol = POLICIES[policy] if isinstance(policy, str) else int(policy)
    C = max_conns
    z = lambda: jnp.zeros((C,), I32)
    zu = lambda: jnp.zeros((C,), U32)
    return {
        "cwnd": jnp.full((C,), IW_SEGS * mss, I32),
        "ssthresh": jnp.full((C,), CWND_MAX, I32),
        "srtt": z(), "rttvar": z(),
        "rto": jnp.full((C,), RTO_INIT, I32),
        "in_rec": z(), "recover": zu(),
        "rtt_seq": zu(), "rtt_ts": z(), "rtt_pending": z(),
        "ecn_end": zu(), "ecn_acked": z(), "ecn_marked": z(),
        "alpha": z(), "ece_cut": z(),
        "retx_fast": z(), "retx_timer": z(), "marks": z(),
        "policy": jnp.asarray(pol, I32),
        "mss": jnp.asarray(mss, I32),
        "now": jnp.asarray(0, I32),
    }


def effective_wnd(cc, i, snd_wnd):
    """Send window = min(cwnd, peer window), in bytes (int32)."""
    return jnp.minimum(snd_wnd.astype(I32), cc["cwnd"][i])


def on_ack(cc, i, *, est, advanced, acked, fast_retx, ece, ack_seq,
           high_seq, flight):
    """Scalar per-connection ACK hook (called from ``tcp.rx_one``).

    est/advanced/fast_retx must already carry the engine's
    packet-to-connection predicate (`act`) so masked batch rows never
    touch slot ``i``.  Returns ``(cc', exit_recovery, partial_ack)`` —
    the engine resets ``dup_acks`` on recovery exit and treats a partial
    ACK like another fast-retransmit trigger (NewReno).
    """
    cc = dict(cc)
    mss = cc["mss"]
    g = lambda k: cc[k][i]

    def setw(k, cond, val):
        cc[k] = cc[k].at[i].set(jnp.where(cond, val.astype(cc[k].dtype),
                                          cc[k][i]))

    # ---- RTT sample (Karn: one outstanding stamped segment) -------------
    covered = advanced & (g("rtt_pending") != 0) & \
        ~_seq_lt(ack_seq, g("rtt_seq"))
    rtt = jnp.maximum(cc["now"] - g("rtt_ts"), 1)
    first = g("srtt") == 0
    err = rtt - (g("srtt") >> 3)
    srtt_n = jnp.where(first, rtt << 3, g("srtt") + err)
    rttvar_n = jnp.where(first, rtt << 1,
                         g("rttvar") + (jnp.abs(err) - (g("rttvar") >> 2)))
    rto_n = jnp.clip((srtt_n >> 3) + jnp.maximum(rttvar_n, 1),
                     RTO_MIN, RTO_MAX)
    setw("srtt", covered, srtt_n)
    setw("rttvar", covered, rttvar_n)
    setw("rto", covered, rto_n)
    # the sample is consumed when covered — and invalidated on fast
    # retransmit (Karn: an ACK after a retransmission is ambiguous)
    setw("rtt_pending", covered | fast_retx, jnp.zeros((), I32))

    cwnd = g("cwnd")
    ssth = g("ssthresh")
    in_rec = g("in_rec") != 0

    ece_now = est & ece
    is_dctcp = cc["policy"] == DCTCP

    # ---- window growth (slow start / congestion avoidance) --------------
    # a classic-policy ECE ack is a congestion signal, not a growth event
    grow = est & advanced & ~in_rec & ~(ece_now & ~is_dctcp)
    inc = jnp.where(cwnd < ssth, jnp.minimum(acked.astype(I32), mss),
                    jnp.maximum((mss * mss) // jnp.maximum(cwnd, 1), 1))
    cwnd = jnp.where(grow, jnp.minimum(cwnd + inc, CWND_MAX), cwnd)

    # ---- ECN bookkeeping -------------------------------------------------
    boundary = est & advanced & ~_seq_lt(ack_seq, g("ecn_end"))
    acked_n = g("ecn_acked") + (est & advanced).astype(I32)
    marked_n = g("ecn_marked") + (ece_now & advanced).astype(I32)
    frac = (marked_n << ALPHA_SHIFT) // jnp.maximum(acked_n, 1)
    alpha_n = g("alpha") + ((frac - g("alpha")) >> ALPHA_G_SHIFT)
    dctcp_cut = boundary & is_dctcp & (marked_n > 0)
    cwnd = jnp.where(
        dctcp_cut,
        jnp.maximum(cwnd - ((cwnd * alpha_n) >> (ALPHA_SHIFT + 1)), mss),
        cwnd)
    setw("alpha", boundary & is_dctcp, alpha_n)
    setw("ecn_acked", est, jnp.where(boundary, 0, acked_n))
    setw("ecn_marked", est, jnp.where(boundary, 0, marked_n))
    setw("ecn_end", boundary, high_seq)
    # classic policy: one multiplicative ECE cut per window (RFC 3168)
    nr_cut = ece_now & ~is_dctcp & (g("ece_cut") == 0) & ~in_rec
    ssth = jnp.where(nr_cut, jnp.maximum(cwnd // 2, 2 * mss), ssth)
    cwnd = jnp.where(nr_cut, ssth, cwnd)
    setw("ece_cut", est,
         jnp.where(boundary & ~nr_cut, 0,
                   jnp.where(nr_cut, 1, g("ece_cut"))))
    setw("marks", ece_now, g("marks") + 1)

    # ---- fast recovery (NewReno) ----------------------------------------
    enter = fast_retx & ~in_rec
    ssth = jnp.where(enter, jnp.maximum(flight.astype(I32) // 2, 2 * mss),
                     ssth)
    cwnd = jnp.where(enter, ssth + 3 * mss, cwnd)
    setw("recover", enter, high_seq)
    setw("retx_fast", fast_retx, g("retx_fast") + 1)

    full = advanced & in_rec & ~_seq_lt(ack_seq, g("recover"))
    partial = advanced & in_rec & _seq_lt(ack_seq, g("recover"))
    cwnd = jnp.where(full, ssth, cwnd)
    in_rec_n = jnp.where(enter, 1, jnp.where(full, 0, in_rec.astype(I32)))

    touched = est | fast_retx
    setw("in_rec", touched, in_rec_n)
    setw("cwnd", touched, cwnd)
    setw("ssthresh", touched, ssth)
    return cc, full, partial


def stamp_rtt(cc, i, end_seq, sending):
    """Arm one RTT sample for new data ending at ``end_seq`` (tx_emit)."""
    cc = dict(cc)
    do = sending & (cc["rtt_pending"][i] == 0)
    cc["rtt_seq"] = cc["rtt_seq"].at[i].set(
        jnp.where(do, end_seq.astype(U32), cc["rtt_seq"][i]))
    cc["rtt_ts"] = cc["rtt_ts"].at[i].set(
        jnp.where(do, cc["now"], cc["rtt_ts"][i]))
    cc["rtt_pending"] = cc["rtt_pending"].at[i].set(
        jnp.where(do, 1, cc["rtt_pending"][i]))
    return cc


def on_timer(cc, expired, flight):
    """Vectorized RTO expiry: multiplicative backoff, cwnd collapse to one
    MSS, recovery abandoned, pending RTT sample invalidated (Karn)."""
    cc = dict(cc)
    mss = cc["mss"]
    cc["ssthresh"] = jnp.where(
        expired, jnp.maximum(flight.astype(I32) // 2, 2 * mss),
        cc["ssthresh"])
    cc["cwnd"] = jnp.where(expired, mss, cc["cwnd"])
    cc["rto"] = jnp.where(expired, jnp.minimum(cc["rto"] * 2, RTO_MAX),
                          cc["rto"])
    cc["in_rec"] = jnp.where(expired, 0, cc["in_rec"])
    cc["rtt_pending"] = jnp.where(expired, 0, cc["rtt_pending"])
    cc["retx_timer"] = cc["retx_timer"] + expired.astype(I32)
    return cc


def tick_clock(cc):
    cc = dict(cc)
    cc["now"] = cc["now"] + 1
    return cc


# ---------------------------------------------------------------------------
# telemetry: one RingLog row per connection per batch


def log_name(conn_idx: int) -> str:
    return f"tcp_cc.{conn_idx}"


def log_rows(cc, step):
    """(C, LOG_WIDTH) counter rows, one per connection.  The LOG_READ-
    visible prefix is [step, cwnd, ssthresh, srtt_ticks, retx<<16|marks];
    the tail words carry in_rec, alpha, policy for full-log dumps."""
    C = cc["cwnd"].shape[0]
    retx = jnp.minimum(cc["retx_fast"] + cc["retx_timer"], 0xFFFF)
    marks = jnp.minimum(cc["marks"], 0xFFFF)
    cols = [
        jnp.full((C,), telemetry.timestamp(step), I32),
        cc["cwnd"],
        jnp.minimum(cc["ssthresh"], 0x7FFFFFFF).astype(I32),
        cc["srtt"] >> 3,
        (retx << 16) | marks,
        cc["in_rec"],
        cc["alpha"],
        jnp.full((C,), cc["policy"], I32),
    ]
    return jnp.stack(cols, axis=1)


def unpack_row(row):
    """Decode a LOG_READ-served cc row prefix into named counters."""
    return {"step": int(row[0]), "cwnd": int(row[1]),
            "ssthresh": int(row[2]), "srtt": int(row[3]),
            "retx": int(row[4]) >> 16, "marks": int(row[4]) & 0xFFFF}
