"""Stack assembly: declarative topologies (the paper's XML analog) compiled
into executable pipelines.

`udp_topology()` is Figure 4 as *configuration*: eth -> ip -> udp -> app(s)
and back, every hop a route entry.  `tcp_topology()` adds the TCP engine
and the optional NAT tiles between IP and TCP (live migration, §5.3) — NAT
is inserted by route edits alone, the paper's Table-1 flexibility claim.

`UdpStack` / `TcpStack` are thin wrappers: they build (or accept) a
topology, hand it to :class:`repro.core.compiler.StackCompiler`, and expose
the compiled pipelines under the original rx_tx / rx / tx_frame APIs.  No
protocol order is hardcoded here — reroute the topology (e.g. with
``TopologyConfig.insert_on_path``) and the executor follows.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compiler import StackCompiler, deep_merge
from repro.core.topology import TopologyConfig
from repro.mgmt import plane as _mgmt_plane    # registers the mgmt tiles
from repro.net import ipinip, ipv4
from repro.net import tiles as _tiles          # noqa: F401  (registers kinds)


def _cached_stream_fn(stack):
    """One donated jit of ``stack.run_stream`` per stack instance.
    Donation invalidates the state argument's buffers — callers must
    thread the returned state and never reuse the donated one."""
    if getattr(stack, "_stream_fn", None) is None:
        stack._stream_fn = jax.jit(stack.run_stream, donate_argnums=(0,))
    return stack._stream_fn


def _bind_or_check_mgmt(topo: TopologyConfig, mgmt_port: int):
    """Bind the management plane, or — when the topology was pre-bound —
    verify the requested port matches the existing binding instead of
    silently black-holing every command sent to the wrong port."""
    if not topo.has_tile("mgmt"):
        meta = _mgmt_plane.bind_mgmt(topo, mgmt_port)
        # a pre-bound watchdog gets its in-band alert endpoint on the
        # ctrl NoC now that a controller exists (deadlock-analyzed)
        from repro.obs import slo as _slo
        _slo.bind_alert_path(topo)
        return meta
    bound = [r.key for r in topo.routes_of("udp_rx")
             if r.next_tile == "mgmt" and r.match == "udp_port"]
    if mgmt_port not in bound:
        raise ValueError(
            f"topology already binds the management port on "
            f"{bound or 'an unknown route'}, but mgmt_port={mgmt_port} "
            f"was requested")
    return None


@dataclasses.dataclass
class AppDecl:
    name: str
    port: int                  # UDP/TCP port (port-match apps: base port)
    n_replicas: int = 1
    policy: str = "round_robin"   # round_robin | flow_hash | port_match
    # process(state, body, blen, meta, active, replica) -> (state, body', blen')
    process: Optional[Callable] = None
    state: object = None


def _place_apps(topo: TopologyConfig, apps: List[AppDecl], row: int):
    x = 3
    for app in apps:
        for r in range(app.n_replicas):
            nm = f"{app.name}.{r}" if app.n_replicas > 1 else app.name
            topo.add_tile(nm, f"app:{app.name}", x, row)
            topo.add_chain("eth_rx", "ip_rx", "udp_rx", nm,
                           "udp_tx", "ip_tx", "eth_tx")
            # reply path: app -> udp_tx -> ip_tx -> eth_tx
            topo.add_route(nm, "const", None, "udp_tx")
            x += 1


def udp_topology_with_nat(apps: List[AppDecl],
                          name="udp-nat-stack") -> TopologyConfig:
    """UDP stack with NAT between IP and UDP, built from the plain
    topology purely via config edits: widen the mesh, shift the downstream
    tiles one column right (a detour placement would re-acquire a channel
    and the deadlock analysis rejects it), insert the tile on the path."""
    topo = udp_topology(apps, name=name)
    topo.dim_x += 1
    shifted = ["udp_rx"] + [t.name for t in topo.tiles
                            if t.kind.startswith("app:")]
    for nm in shifted:
        topo.tile(nm).x += 1
    topo.insert_on_path("nat_rx", "nat_rx", 2, 0, "ip_rx", "udp_rx")
    return topo


def ipinip_udp_topology(apps: List[AppDecl],
                        name="udp-ipinip-stack") -> TopologyConfig:
    """UDP stack behind an IP-in-IP tunnel (paper §3.5/§4.5), built from
    the plain topology purely via `insert_on_path` edits:

      * `ipip_decap` lands between ip_rx and udp_rx, classifying on the
        *outer* header (ip_proto=4 — the match override),
      * a *duplicated* IP tile (`ip_rx_inner`) follows it to parse the
        inner packet — duplication is how the paper breaks the
        repeated-header resource-ordering problem,
      * `ipip_encap` lands between ip_tx and eth_tx on a third mesh row,
        wrapping replies toward the physical host (`outer_src`/`outer_dst`
        compiler options).
    """
    topo = udp_topology(apps, name=name)
    topo.dim_x += 2
    topo.dim_y = 3
    shifted = ["udp_rx"] + [t.name for t in topo.tiles
                            if t.kind.startswith("app:")]
    for nm in shifted:
        topo.tile(nm).x += 2
    topo.insert_on_path("ipip_decap", "ipinip_decap", 2, 0,
                        "ip_rx", "udp_rx",
                        match="ip_proto", key=ipinip.PROTO_IPIP)
    topo.insert_on_path("ip_rx_inner", "ip_rx", 3, 0,
                        "ipip_decap", "udp_rx")
    topo.insert_on_path("ipip_encap", "ipinip_encap", 1, 2,
                        "ip_tx", "eth_tx")
    return topo


def udp_topology(apps: List[AppDecl], name="udp-stack") -> TopologyConfig:
    width = 3 + sum(a.n_replicas for a in apps)
    topo = TopologyConfig(name, max(width, 4), 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    topo.add_tile("udp_rx", "udp_rx", 2, 0)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("udp_tx", "udp_tx", 2, 1)
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_UDP, "udp_rx")
    topo.add_route("udp_tx", "const", None, "ip_tx")
    topo.add_route("ip_tx", "const", None, "eth_tx")
    _place_apps(topo, apps, 0)
    for app in apps:
        if app.policy == "port_match":
            # one CAM entry per shard port (paper: 'distribute work to the
            # tiles by matching on the destination port number')
            for r in range(app.n_replicas):
                nm = f"{app.name}.{r}" if app.n_replicas > 1 else app.name
                topo.add_route("udp_rx", "udp_port", app.port + r, nm)
        else:
            nm = f"{app.name}.0" if app.n_replicas > 1 else app.name
            topo.add_route("udp_rx", "udp_port", app.port, nm)
    return topo


def replicated_udp_topology(apps: List[AppDecl], n_rx: int = 2,
                            policy: str = "flow_hash",
                            name: str = "udp-rss-stack") -> TopologyConfig:
    """UDP stack with the hot `udp_rx` parser replicated ``n_rx`` times
    behind an RSS dispatch group — pure config edits on the plain
    topology (the NAT-insertion pattern): widen the mesh, shift the app
    tiles right to free a run of row-0 coordinates, then
    `scaleout.replicate` the parser onto them.  Upstream routes keep
    naming "udp_rx"; the compiler lowers the group to one dispatch stage
    whose policy table is runtime state (drain/restore with no retrace)."""
    from repro.core import scaleout
    topo = udp_topology(apps, name=name)
    topo.dim_x += n_rx - 1
    for t in topo.tiles:
        if t.kind.startswith("app:"):
            t.x += n_rx - 1
    coords = [(2 + i, 0) for i in range(n_rx)]
    base_port = (apps[0].port if policy == "port_match" and apps else None)
    scaleout.replicate(topo, "udp_rx", n_rx, coords, policy=policy,
                       base_port=base_port)
    return topo


def rpc_serve_topology(tiles: List[Tuple[str, str, int]],
                       name: str = "rpc-serve-stack",
                       params: Optional[dict] = None) -> TopologyConfig:
    """Direct-attached serving topology: eth -> ip -> udp, then the app
    tiles dispatched on the RPC frame's ``msg_type`` (the ``rpc_msg``
    match space) — the request *kind* picks the accelerator tile, on any
    UDP port.  ``tiles`` is a list of (tile_name, tile_kind, msg_type)
    triples, e.g.::

        rpc_serve_topology([("lm", "lm_serve", rpc.MSG_LM_GENERATE),
                            ("rs", "rs_serve", rpc.MSG_RS_ENCODE)])

    Like every keyed route, the msg_type CAM (``udp_rx:rpc_msg``) is a
    runtime table: the management plane can rebind a message type to
    another tile live.  ``params`` maps tile_name -> TileDecl params
    (e.g. {"rs": {"use_pallas": True}})."""
    params = params or {}
    topo = TopologyConfig(name, max(4, 3 + len(tiles)), 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    topo.add_tile("udp_rx", "udp_rx", 2, 0)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("udp_tx", "udp_tx", 2, 1)
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_UDP, "udp_rx")
    topo.add_route("udp_tx", "const", None, "ip_tx")
    topo.add_route("ip_tx", "const", None, "eth_tx")
    for i, (nm, kind, msg) in enumerate(tiles):
        topo.add_tile(nm, kind, 3 + i, 0, params=params.get(nm))
        topo.add_chain("eth_rx", "ip_rx", "udp_rx", nm,
                       "udp_tx", "ip_tx", "eth_tx")
        topo.add_route("udp_rx", "rpc_msg", msg, nm)
        topo.add_route(nm, "const", None, "udp_tx")
    return topo


class UdpStack:
    """Figure-4 pipeline, compiled from its topology, jittable end to end.

    Pass ``mgmt_port=<udp port>`` to bind the in-band management plane
    (paper §3.6/§4.6): control frames on that port reach the compiled
    `mgmt` tile, and the controller/endpoint distribution paths are
    declared on their own ``ctrl`` NoC (compiled as `ctrl_pipe`)."""

    def __init__(self, apps: List[AppDecl], local_ip: int,
                 check_deadlock: bool = True,
                 topo: Optional[TopologyConfig] = None,
                 nat_entries=None, with_telemetry: bool = True,
                 mgmt_port: Optional[int] = None,
                 options: Optional[dict] = None,
                 with_obs: bool = True):
        self.topo = topo if topo is not None else udp_topology(apps)
        self.apps = apps
        self.local_ip = local_ip
        self.with_telemetry = with_telemetry
        self.with_obs = with_obs
        self.mgmt_port = mgmt_port
        self.mgmt_meta = None
        if mgmt_port is not None:
            self.mgmt_meta = _bind_or_check_mgmt(self.topo, mgmt_port)
        opts = {"local_ip": local_ip, "nat_entries": nat_entries or []}
        opts.update(options or {})
        self.compiler = StackCompiler(
            self.topo, bindings={a.name: a for a in apps},
            options=opts, check_deadlock=check_deadlock)
        self.pipeline = self.compiler.compile("eth_rx")
        self.ctrl_pipe = None
        if mgmt_port is not None:
            self.ctrl_pipe = StackCompiler(
                self.topo, options=opts, check_deadlock=False,
                noc="ctrl").compile(
                    (self.mgmt_meta or {}).get("ctrl_in", "ctrl_in"))

    def init_state(self):
        st = self.pipeline.init_state(with_telemetry=self.with_telemetry,
                                      with_obs=self.with_obs)
        st["rx_count"] = jnp.zeros((), jnp.int32)
        return st

    def rx_tx(self, state, payload, length):
        """Full compiled chain: parse -> dispatch -> app -> build.  Returns
        (state', out_payload, out_length, out_valid, info)."""
        state, carrier = self.pipeline.run(
            state, {"payload": payload, "length": length})
        state["rx_count"] = state["rx_count"] + \
            carrier["alive"].sum(dtype=jnp.int32)
        return (state, carrier["tx_payload"], carrier["tx_len"],
                carrier["alive"], carrier["info"])

    def run_stream(self, state, payloads, lengths):
        """Streamed rx_tx: N batches (a (N, B, L) frame arena + (N, B)
        lengths) device-resident under one scan — one dispatch, no host
        round trips between batches.  Returns (state', outs) with outs
        holding stacked ``tx_payload`` / ``tx_len`` / ``alive`` / ``info``
        (plus the push-observability ``pc_*`` / ``alert_*`` frames when
        the topology carries an int_mirror / watchdog tile).
        Bit-identical to N sequential :meth:`rx_tx` calls."""
        state, outs = self.pipeline.run_stream(state, payloads, lengths)
        state = dict(state)
        state["rx_count"] = state["rx_count"] + \
            outs["alive"].sum(dtype=jnp.int32)
        return state, outs

    def stream_fn(self):
        """The jitted streaming entry point with the state carry
        *donated*: ``state, outs = stack.stream_fn()(state, arena.payload,
        arena.length)``.  Donation lets XLA reuse the state buffers
        in place across calls — callers must thread the returned state and
        never touch the donated argument again."""
        return _cached_stream_fn(self)


# ---------------------------------------------------------------------------
# TCP stack with optional NAT (live migration)


def tcp_topology(with_nat: bool = False, name="tcp-stack",
                 cc_policy: Optional[str] = None) -> TopologyConfig:
    """``cc_policy`` ("newreno" | "dctcp") is a *tile parameter* on the
    tcp_rx TileDecl — the congestion-control engine is selected by
    configuration, exactly like inserting NAT; None keeps the seed
    engine bit-identically."""
    topo = TopologyConfig(name, 6, 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    x = 2
    if with_nat:
        topo.add_tile("nat_rx", "nat_rx", 2, 0)
        topo.add_tile("nat_tx", "nat_tx", 2, 1)
        x = 3
    topo.add_tile("tcp_rx", "tcp_rx", x, 0,
                  params=({"cc_policy": cc_policy} if cc_policy else None))
    topo.add_tile("tcp_tx", "tcp_tx", x, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ctrl", "controller", x + 1, 1, noc="ctrl")
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    if with_nat:
        topo.add_chain("eth_rx", "ip_rx", "nat_rx", "tcp_rx",
                       "tcp_tx", "nat_tx", "ip_tx", "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "nat_rx")
        topo.add_route("nat_rx", "const", None, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "nat_tx")
        topo.add_route("nat_tx", "const", None, "ip_tx")
    else:
        topo.add_chain("eth_rx", "ip_rx", "tcp_rx", "tcp_tx", "ip_tx",
                       "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "ip_tx")
    return topo


class TcpStack:
    """TCP stack with optional NAT tiles for live migration.  The RX chain
    and the TX build chain are both compiled from the topology's routes.

    Management stays UDP even on the TCP stack (paper §4.6): with
    ``mgmt_port=...`` the binding adds the UDP parser/builder tiles and
    routes control frames to the `mgmt` tile; use :meth:`rx_mgmt` to get
    the in-band reply frames alongside the TCP engine responses."""

    def __init__(self, local_ip: int, with_nat: bool = False,
                 nat_entries=None, max_conns: int = 16,
                 topo: Optional[TopologyConfig] = None,
                 with_telemetry: bool = True,
                 mgmt_port: Optional[int] = None,
                 cc_policy: Optional[str] = None,
                 options: Optional[dict] = None,
                 with_obs: bool = True):
        self.topo = topo if topo is not None else \
            tcp_topology(with_nat, cc_policy=cc_policy)
        self.with_nat = with_nat
        self.local_ip = local_ip
        self.max_conns = max_conns
        self.nat_entries = nat_entries or []
        self.with_telemetry = with_telemetry
        self.with_obs = with_obs
        self.mgmt_port = mgmt_port
        self.mgmt_meta = None
        if mgmt_port is not None:
            self.mgmt_meta = _bind_or_check_mgmt(self.topo, mgmt_port)
        opts = {"local_ip": local_ip, "max_conns": max_conns,
                "nat_entries": self.nat_entries}
        opts.update(options or {})
        self.compiler = StackCompiler(self.topo, options=opts)
        self.rx_pipe = self.compiler.compile("eth_rx")
        self.tx_pipe = self.compiler.compile("tcp_tx")
        self.ctrl_pipe = None
        if mgmt_port is not None:
            self.ctrl_pipe = StackCompiler(
                self.topo, options={"local_ip": local_ip},
                check_deadlock=False, noc="ctrl").compile(
                    (self.mgmt_meta or {}).get("ctrl_in", "ctrl_in"))

    def init_state(self):
        # route tables live in shared state but hold *per-pipeline* node
        # indices: a table name appearing in both pipelines would let one
        # silently clobber the other at deep_merge time — refuse early
        clash = set(self.rx_pipe.table_entries) & \
            set(self.tx_pipe.table_entries)
        if clash:
            raise ValueError(
                f"route tables {sorted(clash)} are keyed by both the RX "
                f"and TX pipelines; re-name or re-place the source tiles "
                f"so each keyed route belongs to one pipeline")
        st = self.rx_pipe.init_state(with_telemetry=self.with_telemetry,
                                     with_obs=self.with_obs)
        # the TX chain gets no RingLogs: tx_frame returns only the built
        # frame (original API), so TX-side log writes could never persist —
        # telemetry covers the RX path
        deep_merge(st, self.tx_pipe.init_state(with_telemetry=False))
        return st

    def rx(self, state, payload, length):
        """RX chain through optional NAT into the TCP engine.  Returns
        (state', responses) — responses are reply-segment field batches."""
        state, carrier = self.rx_pipe.run(
            state, {"payload": payload, "length": length})
        return state, carrier["tcp_resps"]

    def run_stream(self, state, payloads, lengths):
        """Streamed RX: N inbound batches through the compiled RX chain
        under one scan.  Returns (state', outs) where
        ``outs["tcp_resps"]`` holds the engine's reply-segment field
        batches stacked (N, B, ...).  Bit-identical to N sequential
        :meth:`rx` calls."""
        return self.rx_pipe.run_stream(state, payloads, lengths,
                                       out_keys=("tcp_resps",))

    def stream_fn(self):
        """Jitted streamed RX with the state carry donated (see
        ``UdpStack.stream_fn``)."""
        return _cached_stream_fn(self)

    def rx_mgmt(self, state, payload, length):
        """RX with the management branch: returns (state', tcp_resps,
        mgmt_tx_payload, mgmt_tx_len, mgmt_mask) — rows of the batch that
        were management commands get in-band reply frames."""
        state, carrier = self.rx_pipe.run(
            state, {"payload": payload, "length": length})
        n = payload.shape[0]
        mask = carrier["info"].get("mgmt", jnp.zeros((n,), bool))
        mask = mask & carrier.get("alive", jnp.ones((n,), bool))
        return (state, carrier["tcp_resps"], carrier.get("tx_payload"),
                carrier.get("tx_len"), mask)

    def tx_frame(self, state, seg_meta, data, dlen):
        """Build one TX frame from an emitted segment (through NAT)."""
        payload = data.reshape(1, -1) if data.ndim == 1 else data
        dl = dlen.reshape(1) if dlen.ndim == 0 else dlen
        mm = {k: (v.reshape(1) if v.ndim == 0 else v)
              for k, v in seg_meta.items()}
        # with_telemetry=False: the returned state is discarded (original
        # API), and the stacked node log in the shared state belongs to
        # the RX pipeline — the TX chain must not write into it
        _, carrier = self.tx_pipe.run(
            state, {"payload": payload, "length": dl, "meta": mm},
            with_telemetry=False)
        return carrier["tx_payload"], carrier["tx_len"]
