"""Stack assembly: declarative topologies (the paper's XML analog) and the
jittable RX/TX pipelines that implement them.

`udp_stack()` is Figure 4: eth -> ip -> udp -> app(s) and back.  Apps are
registered with a dispatch policy (round-robin / flow-hash / port-match);
the topology is validated + deadlock-checked at build time, and the
returned `UdpStack` executes the full chain on packet batches.

`tcp_stack()` adds the TCP engine and the optional NAT tiles between IP
and TCP (live migration, §5.3) — inserted *without modifying* eth/ip/tcp,
which is the paper's Table-1 flexibility claim.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import deadlock
from repro.core.scaleout import (DispatchState, by_flow_hash, by_port,
                                 make_dispatch, round_robin)
from repro.core.topology import TopologyConfig
from repro.net import eth, ipv4, nat as nat_mod, rpc, tcp, udp


@dataclasses.dataclass
class AppDecl:
    name: str
    port: int                  # UDP/TCP port (port-match apps: base port)
    n_replicas: int = 1
    policy: str = "round_robin"   # round_robin | flow_hash | port_match
    # process(state, body, blen, meta, active) -> (state, body', blen')
    process: Optional[Callable] = None
    state: object = None


def _place_apps(topo: TopologyConfig, apps: List[AppDecl], row: int):
    x = 3
    for app in apps:
        for r in range(app.n_replicas):
            nm = f"{app.name}.{r}" if app.n_replicas > 1 else app.name
            topo.add_tile(nm, f"app:{app.name}", x, row)
            topo.add_chain("eth_rx", "ip_rx", "udp_rx", nm,
                           "udp_tx", "ip_tx", "eth_tx")
            x += 1


def udp_topology(apps: List[AppDecl], name="udp-stack") -> TopologyConfig:
    width = 3 + sum(a.n_replicas for a in apps)
    topo = TopologyConfig(name, max(width, 4), 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    topo.add_tile("udp_rx", "udp_rx", 2, 0)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("udp_tx", "udp_tx", 2, 1)
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_UDP, "udp_rx")
    _place_apps(topo, apps, 0)
    for app in apps:
        nm = f"{app.name}.0" if app.n_replicas > 1 else app.name
        topo.add_route("udp_rx", "udp_port", app.port, nm)
    return topo


class UdpStack:
    """Figure-4 pipeline, jittable end to end."""

    def __init__(self, apps: List[AppDecl], local_ip: int,
                 check_deadlock: bool = True):
        self.topo = udp_topology(apps)
        errs = self.topo.validate()
        if errs:
            raise ValueError("\n".join(errs))
        if check_deadlock:
            deadlock.assert_deadlock_free(self.topo)
        self.apps = apps
        self.local_ip = local_ip

    def init_state(self):
        st = {"dispatch": {}, "apps": {}, "rx_count": jnp.zeros((), jnp.int32)}
        for a in self.apps:
            st["dispatch"][a.name] = make_dispatch(list(range(a.n_replicas)))
            st["apps"][a.name] = a.state
        return st

    def rx_tx(self, state, payload, length):
        """Full chain: parse -> dispatch -> app -> build.  Returns
        (state', out_payload, out_length, out_valid, info)."""
        p, l, m = eth.parse(payload, length)
        is_ip = m["ethertype"] == eth.ETHERTYPE_IPV4
        p, l, m2, ok_ip = ipv4.parse(p, l)
        m.update(m2)
        is_udp = m["ip_proto"] == ipv4.PROTO_UDP
        p, l, m3, ok_udp = udp.parse(p, l, m)
        m = m3
        alive = is_ip & ok_ip & is_udp & ok_udp

        body, blen, rmeta, ok_rpc = rpc.parse(p, l)
        m.update(rmeta)
        alive &= ok_rpc

        out_body = body
        out_blen = blen
        info = {}
        for a in self.apps:
            at_app = alive & (m["dst_port"] == a.port) if a.policy != \
                "port_match" else alive & (m["dst_port"] >= a.port) & \
                (m["dst_port"] < a.port + a.n_replicas)
            d = state["dispatch"][a.name]
            if a.policy == "round_robin":
                d, replica_tile = round_robin(d, at_app)
            elif a.policy == "flow_hash":
                replica_tile = by_flow_hash(d, m)
            else:
                replica_tile = by_port(d, m["dst_port"], a.port)
            state["dispatch"][a.name] = d
            ast = state["apps"][a.name]
            ast, nb, nl = a.process(ast, body, blen, m,
                                    at_app, replica_tile)
            state["apps"][a.name] = ast
            out_body = jnp.where(at_app[:, None], nb, out_body)
            out_blen = jnp.where(at_app, nl, out_blen)
            info[a.name] = at_app

        # TX chain: rpc -> udp -> ip -> eth with swapped fields
        q, ql = rpc.build(out_body, out_blen, m["msg_type"], m["req_id"])
        mtx = dict(m)
        mtx["src_ip"], mtx["dst_ip"] = m["dst_ip"], m["src_ip"]
        mtx["src_port"], mtx["dst_port"] = m["dst_port"], m["src_port"]
        mtx["ip_proto"] = jnp.full_like(m["src_ip"], ipv4.PROTO_UDP)
        q, ql = udp.build(q, ql, mtx)
        q, ql = ipv4.build(q, ql, mtx)
        mtx["eth_dst_hi"], mtx["eth_dst_lo"] = m["eth_src_hi"], m["eth_src_lo"]
        mtx["eth_src_hi"], mtx["eth_src_lo"] = m["eth_dst_hi"], m["eth_dst_lo"]
        q, ql = eth.build(q, ql, mtx)
        state["rx_count"] = state["rx_count"] + alive.sum(dtype=jnp.int32)
        return state, q, ql, alive, info


# ---------------------------------------------------------------------------
# TCP stack with optional NAT (live migration)


def tcp_topology(with_nat: bool = False, name="tcp-stack") -> TopologyConfig:
    topo = TopologyConfig(name, 6, 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    x = 2
    if with_nat:
        topo.add_tile("nat_rx", "nat", 2, 0)
        topo.add_tile("nat_tx", "nat", 2, 1)
        x = 3
    topo.add_tile("tcp_rx", "tcp_rx", x, 0)
    topo.add_tile("tcp_tx", "tcp_tx", x, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ctrl", "controller", x + 1, 1, noc="ctrl")
    if with_nat:
        topo.add_chain("eth_rx", "ip_rx", "nat_rx", "tcp_rx",
                       "tcp_tx", "nat_tx", "ip_tx", "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "nat_rx")
        topo.add_route("nat_rx", "const", None, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "nat_tx")
        topo.add_route("nat_tx", "const", None, "ip_tx")
    else:
        topo.add_chain("eth_rx", "ip_rx", "tcp_rx", "tcp_tx", "ip_tx",
                       "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "ip_tx")
    return topo


class TcpStack:
    """TCP stack with optional NAT tiles for live migration."""

    def __init__(self, local_ip: int, with_nat: bool = False,
                 nat_entries=None, max_conns: int = 16):
        self.topo = tcp_topology(with_nat)
        deadlock.assert_deadlock_free(self.topo)
        self.with_nat = with_nat
        self.local_ip = local_ip
        self.max_conns = max_conns
        self.nat_entries = nat_entries or []

    def init_state(self):
        st = {"conn": tcp.init(self.max_conns, local_ip=self.local_ip)}
        if self.with_nat:
            st["nat"] = nat_mod.init(self.nat_entries)
        return st

    def rx(self, state, payload, length):
        """RX chain through optional NAT into the TCP engine.  Returns
        (state', responses) — responses are reply-segment field batches."""
        p, l, m = eth.parse(payload, length)
        p, l, m2, ok = ipv4.parse(p, l)
        m.update(m2)
        if self.with_nat:
            m, _ = nat_mod.rx(state["nat"], m)
        data, dlen, m = tcp.parse_segment(p, l, m)
        conn, resps = tcp.rx_batch(state["conn"], data, dlen, m)
        state = dict(state)
        state["conn"] = conn
        return state, resps

    def tx_frame(self, state, seg_meta, data, dlen):
        """Build one TX frame from an emitted segment (through NAT)."""
        m = dict(seg_meta)
        if self.with_nat:
            m, _ = nat_mod.tx(state["nat"], m)
        B = data.shape[0] if data.ndim > 1 else 1
        payload = data.reshape(1, -1) if data.ndim == 1 else data
        q, ql = tcp.build_segment(
            payload, dlen.reshape(1) if dlen.ndim == 0 else dlen,
            {k: (v.reshape(1) if v.ndim == 0 else v) for k, v in m.items()
             if k in ("src_ip", "dst_ip", "src_port", "dst_port", "tcp_seq",
                      "tcp_ack", "tcp_flags", "tcp_wnd")})
        mm = {k: (v.reshape(1) if v.ndim == 0 else v) for k, v in m.items()}
        mm["ip_proto"] = jnp.full((q.shape[0],), ipv4.PROTO_TCP, jnp.uint32)
        q, ql = ipv4.build(q, ql, mm)
        return q, ql
