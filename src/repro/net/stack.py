"""Stack assembly: declarative topologies (the paper's XML analog) compiled
into executable pipelines.

`udp_topology()` is Figure 4 as *configuration*: eth -> ip -> udp -> app(s)
and back, every hop a route entry.  `tcp_topology()` adds the TCP engine
and the optional NAT tiles between IP and TCP (live migration, §5.3) — NAT
is inserted by route edits alone, the paper's Table-1 flexibility claim.

`UdpStack` / `TcpStack` are thin wrappers: they build (or accept) a
topology, hand it to :class:`repro.core.compiler.StackCompiler`, and expose
the compiled pipelines under the original rx_tx / rx / tx_frame APIs.  No
protocol order is hardcoded here — reroute the topology (e.g. with
``TopologyConfig.insert_on_path``) and the executor follows.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax.numpy as jnp

from repro.core.compiler import StackCompiler, deep_merge
from repro.core.topology import TopologyConfig
from repro.net import ipv4
from repro.net import tiles as _tiles          # noqa: F401  (registers kinds)


@dataclasses.dataclass
class AppDecl:
    name: str
    port: int                  # UDP/TCP port (port-match apps: base port)
    n_replicas: int = 1
    policy: str = "round_robin"   # round_robin | flow_hash | port_match
    # process(state, body, blen, meta, active, replica) -> (state, body', blen')
    process: Optional[Callable] = None
    state: object = None


def _place_apps(topo: TopologyConfig, apps: List[AppDecl], row: int):
    x = 3
    for app in apps:
        for r in range(app.n_replicas):
            nm = f"{app.name}.{r}" if app.n_replicas > 1 else app.name
            topo.add_tile(nm, f"app:{app.name}", x, row)
            topo.add_chain("eth_rx", "ip_rx", "udp_rx", nm,
                           "udp_tx", "ip_tx", "eth_tx")
            # reply path: app -> udp_tx -> ip_tx -> eth_tx
            topo.add_route(nm, "const", None, "udp_tx")
            x += 1


def udp_topology(apps: List[AppDecl], name="udp-stack") -> TopologyConfig:
    width = 3 + sum(a.n_replicas for a in apps)
    topo = TopologyConfig(name, max(width, 4), 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    topo.add_tile("udp_rx", "udp_rx", 2, 0)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("udp_tx", "udp_tx", 2, 1)
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_UDP, "udp_rx")
    topo.add_route("udp_tx", "const", None, "ip_tx")
    topo.add_route("ip_tx", "const", None, "eth_tx")
    _place_apps(topo, apps, 0)
    for app in apps:
        if app.policy == "port_match":
            # one CAM entry per shard port (paper: 'distribute work to the
            # tiles by matching on the destination port number')
            for r in range(app.n_replicas):
                nm = f"{app.name}.{r}" if app.n_replicas > 1 else app.name
                topo.add_route("udp_rx", "udp_port", app.port + r, nm)
        else:
            nm = f"{app.name}.0" if app.n_replicas > 1 else app.name
            topo.add_route("udp_rx", "udp_port", app.port, nm)
    return topo


class UdpStack:
    """Figure-4 pipeline, compiled from its topology, jittable end to end."""

    def __init__(self, apps: List[AppDecl], local_ip: int,
                 check_deadlock: bool = True,
                 topo: Optional[TopologyConfig] = None,
                 nat_entries=None, with_telemetry: bool = True):
        self.topo = topo if topo is not None else udp_topology(apps)
        self.apps = apps
        self.local_ip = local_ip
        self.with_telemetry = with_telemetry
        self.compiler = StackCompiler(
            self.topo, bindings={a.name: a for a in apps},
            options={"local_ip": local_ip, "nat_entries": nat_entries or []},
            check_deadlock=check_deadlock)
        self.pipeline = self.compiler.compile("eth_rx")

    def init_state(self):
        st = self.pipeline.init_state(with_telemetry=self.with_telemetry)
        st["rx_count"] = jnp.zeros((), jnp.int32)
        return st

    def rx_tx(self, state, payload, length):
        """Full compiled chain: parse -> dispatch -> app -> build.  Returns
        (state', out_payload, out_length, out_valid, info)."""
        state, carrier = self.pipeline.run(
            state, {"payload": payload, "length": length})
        state["rx_count"] = state["rx_count"] + \
            carrier["alive"].sum(dtype=jnp.int32)
        return (state, carrier["tx_payload"], carrier["tx_len"],
                carrier["alive"], carrier["info"])


# ---------------------------------------------------------------------------
# TCP stack with optional NAT (live migration)


def tcp_topology(with_nat: bool = False, name="tcp-stack") -> TopologyConfig:
    topo = TopologyConfig(name, 6, 2)
    topo.add_tile("eth_rx", "eth_rx", 0, 0)
    topo.add_tile("ip_rx", "ip_rx", 1, 0)
    x = 2
    if with_nat:
        topo.add_tile("nat_rx", "nat_rx", 2, 0)
        topo.add_tile("nat_tx", "nat_tx", 2, 1)
        x = 3
    topo.add_tile("tcp_rx", "tcp_rx", x, 0)
    topo.add_tile("tcp_tx", "tcp_tx", x, 1)
    topo.add_tile("ip_tx", "ip_tx", 1, 1)
    topo.add_tile("eth_tx", "eth_tx", 0, 1)
    topo.add_tile("ctrl", "controller", x + 1, 1, noc="ctrl")
    topo.add_route("eth_rx", "ethertype", 0x0800, "ip_rx")
    if with_nat:
        topo.add_chain("eth_rx", "ip_rx", "nat_rx", "tcp_rx",
                       "tcp_tx", "nat_tx", "ip_tx", "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "nat_rx")
        topo.add_route("nat_rx", "const", None, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "nat_tx")
        topo.add_route("nat_tx", "const", None, "ip_tx")
    else:
        topo.add_chain("eth_rx", "ip_rx", "tcp_rx", "tcp_tx", "ip_tx",
                       "eth_tx")
        topo.add_route("ip_rx", "ip_proto", ipv4.PROTO_TCP, "tcp_rx")
        topo.add_route("tcp_tx", "const", None, "ip_tx")
    return topo


class TcpStack:
    """TCP stack with optional NAT tiles for live migration.  The RX chain
    and the TX build chain are both compiled from the topology's routes."""

    def __init__(self, local_ip: int, with_nat: bool = False,
                 nat_entries=None, max_conns: int = 16,
                 topo: Optional[TopologyConfig] = None,
                 with_telemetry: bool = True):
        self.topo = topo if topo is not None else tcp_topology(with_nat)
        self.with_nat = with_nat
        self.local_ip = local_ip
        self.max_conns = max_conns
        self.nat_entries = nat_entries or []
        self.with_telemetry = with_telemetry
        self.compiler = StackCompiler(
            self.topo, options={"local_ip": local_ip, "max_conns": max_conns,
                                "nat_entries": self.nat_entries})
        self.rx_pipe = self.compiler.compile("eth_rx")
        self.tx_pipe = self.compiler.compile("tcp_tx")

    def init_state(self):
        st = self.rx_pipe.init_state(with_telemetry=self.with_telemetry)
        # the TX chain gets no RingLogs: tx_frame returns only the built
        # frame (original API), so TX-side log writes could never persist —
        # telemetry covers the RX path
        deep_merge(st, self.tx_pipe.init_state(with_telemetry=False))
        return st

    def rx(self, state, payload, length):
        """RX chain through optional NAT into the TCP engine.  Returns
        (state', responses) — responses are reply-segment field batches."""
        state, carrier = self.rx_pipe.run(
            state, {"payload": payload, "length": length})
        return state, carrier["tcp_resps"]

    def tx_frame(self, state, seg_meta, data, dlen):
        """Build one TX frame from an emitted segment (through NAT)."""
        payload = data.reshape(1, -1) if data.ndim == 1 else data
        dl = dlen.reshape(1) if dlen.ndim == 0 else dlen
        mm = {k: (v.reshape(1) if v.ndim == 0 else v)
              for k, v in seg_meta.items()}
        _, carrier = self.tx_pipe.run(
            state, {"payload": payload, "length": dl, "meta": mm})
        return carrier["tx_payload"], carrier["tx_len"]
