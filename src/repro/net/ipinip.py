"""IP-in-IP encapsulation tile (paper §4.5) — the other network-
virtualization option.  Encap prepends an outer IPv4 header addressed to
the physical host; decap strips it.  Decap requires a *second* IP tile
downstream (duplicated tiles break the repeated-header resource-ordering
problem, paper §3.5 — tests/test_core.py reproduces the analysis).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.net import bytesops as B
from repro.net import ipv4

PROTO_IPIP = 4


def encap(payload, length, meta: Dict, outer_src, outer_dst):
    """Wrap the current (inner IP) packet in an outer IPv4 header."""
    m = {"ip_proto": jnp.full_like(meta["src_ip"], PROTO_IPIP),
         "src_ip": jnp.broadcast_to(jnp.uint32(outer_src), meta["src_ip"].shape)
         if not hasattr(outer_src, "shape") else outer_src,
         "dst_ip": jnp.broadcast_to(jnp.uint32(outer_dst), meta["dst_ip"].shape)
         if not hasattr(outer_dst, "shape") else outer_dst}
    return ipv4.build(payload, length, m)


def decap(payload, length, meta: Dict):
    """Strip the outer header (we are already past the outer IP tile, so
    the payload *is* the inner IP packet); just sanity-check the proto."""
    ok = meta["ip_proto"] == PROTO_IPIP
    return payload, length, ok
