"""Registered tile functions for every protocol element.

Importing this module populates the :mod:`repro.core.compiler` registry:
each tile *kind* that can appear in a TopologyConfig maps to one jittable
function here.  The compiler wires them together from the declared routes —
none of these functions knows what comes before or after it in the chain,
which is exactly the paper's tile-independence property (insert NAT or
IP-in-IP between any two tiles without touching either).

Carrier keys (RX direction): ``payload``/``length`` (current packet view),
``meta`` (accumulated header fields), ``alive`` (RX-chain conjunction,
maintained by the executor), ``body``/``blen`` (RPC body for apps),
``out_body``/``out_blen`` (app-modified reply body).  TX direction:
``tx_payload``/``tx_len``/``tx_meta`` and ``tx_csum_offset`` (where the L4
checksum lives, for NAT's incremental fixup).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.core.compiler import register_tile
from repro.net import eth, ipinip, ipv4, nat as nat_mod, rpc, tcp, udp
from repro.obs import reasons
from repro.transport import cc as ccmod, rate as rate_mod

# ---------------------------------------------------------------------------
# RX protocol tiles


@register_tile("eth_rx", alive=True, rewrites=("ethertype",))
def eth_rx(state, carrier, pred, ctx):
    p, l, m = eth.parse(carrier["payload"], carrier["length"])
    carrier.update(payload=p, length=l, meta=m)
    return state, carrier, None


@register_tile("ip_rx", alive=True, rewrites=("ip_proto",))
def ip_rx(state, carrier, pred, ctx):
    p, l, m2, ok, reason = ipv4.parse_ex(carrier["payload"],
                                         carrier["length"])
    m = dict(carrier["meta"])
    m.update(m2)
    carrier.update(payload=p, length=l, meta=m, drop_reason=reason)
    return state, carrier, ok


def _udp_init(ctx):
    # dispatch-side token buckets (mgmt RATE_SET); empty table = unlimited
    return {"rate": rate_mod.init()}


@register_tile("udp_rx", init=_udp_init, alive=True)
def udp_rx(state, carrier, pred, ctx):
    """UDP parse + RPC deframing (the app-facing boundary of the paper's
    UDP tile: apps receive framed request bodies, not raw datagrams).
    Dispatch applies the per-port token buckets here: packets beyond a
    rate-limited port's bucket drop exactly like a parse failure."""
    p, l, m, ok_udp, r_udp = udp.parse_ex(carrier["payload"],
                                          carrier["length"],
                                          carrier["meta"])
    body, blen, rmeta, ok_rpc, r_rpc = rpc.parse_ex(p, l)
    m = dict(m)
    m.update(rmeta)
    carrier.update(payload=p, length=l, meta=m, body=body, blen=blen,
                   out_body=body, out_blen=blen)
    ok = ok_udp & ok_rpc
    # first failing layer attributes the drop: udp, then rpc, then rate
    reason = jnp.where(~ok_udp, r_udp, jnp.where(~ok_rpc, r_rpc, 0))
    if "rate" in state:
        rt, ok_rate = rate_mod.apply(state["rate"], m["dst_port"],
                                     pred & ok)
        state = dict(state)
        state["rate"] = rt
        reason = jnp.where(ok & ~ok_rate, reasons.RATE_LIMIT, reason)
        ok = ok & ok_rate
    carrier["drop_reason"] = reason
    return state, carrier, ok


def _nat_init(ctx):
    return {"nat": nat_mod.init(ctx.options.get("nat_entries"))}


@register_tile("nat_rx", init=_nat_init, alive=True)
def nat_rx(state, carrier, pred, ctx):
    """Virtual dst -> physical dst, patching the L4 checksum in place so
    downstream verification still passes (RFC 1624 incremental update)."""
    m = carrier["meta"]
    old_dst = m["dst_ip"]
    m2, found = nat_mod.rx(state["nat"], m)
    p = carrier["payload"]
    proto = m["ip_proto"]
    p = nat_mod.fixup_l4_checksum(p, 6, old_dst, m2["dst_ip"],
                                  found & (proto == ipv4.PROTO_UDP))
    p = nat_mod.fixup_l4_checksum(p, 16, old_dst, m2["dst_ip"],
                                  found & (proto == ipv4.PROTO_TCP),
                                  zero_is_disabled=False)
    carrier.update(payload=p, meta=m2)
    return state, carrier, None


@register_tile("ipinip_decap", alive=True)
def ipinip_decap(state, carrier, pred, ctx):
    """Strip the outer header; a *duplicated* ip_rx tile must sit
    downstream to parse the inner packet (paper §3.5)."""
    p, l, ok = ipinip.decap(carrier["payload"], carrier["length"],
                            carrier["meta"])
    carrier.update(payload=p, length=l)
    carrier["drop_reason"] = jnp.where(
        pred & ~ok, reasons.IPIP_BAD, 0).astype(jnp.int32)
    return state, carrier, ok


def _tcp_init(ctx):
    """The CC policy is a *tile parameter* (``cc_policy`` on the tcp_rx
    TileDecl; compiler option as fallback) — selecting NewReno vs the ECN
    policy vs the bare seed engine is a topology edit, not an engine
    fork.  When CC is on, every connection gets a ``tcp_cc.<i>`` RingLog
    so cwnd/ssthresh/rtt/retx/marks are LOG_READ-able in-band."""
    pol = None
    for t in ctx.members:
        pol = t.params.get("cc_policy", pol)
    if pol is None:
        pol = ctx.options.get("cc_policy")
    max_conns = ctx.options.get("max_conns", 16)
    st = {"conn": tcp.init(
        max_conns, local_ip=ctx.options["local_ip"], cc_policy=pol,
        mss=ctx.options.get("mss", 1460),
        rx_buf=ctx.options.get("tcp_rx_buf", 4096),
        tx_buf=ctx.options.get("tcp_tx_buf", 4096))}
    if pol is not None:
        st["telemetry"] = {
            "step": jnp.zeros((), jnp.int32),
            "logs": {ccmod.log_name(i):
                     telemetry.make_log(telemetry.PIPE_LOG_ENTRIES)
                     for i in range(max_conns)}}
    return st


@register_tile("tcp_rx", init=_tcp_init)
def tcp_rx(state, carrier, pred, ctx):
    """Parse segments and drive the connection-table engine.  Processes the
    whole batch in arrival order (the engine's lookup drops non-matching
    segments itself, like the hardware tile).  Rows that did not arrive
    here (`pred` false — e.g. UDP management frames sharing the batch) are
    masked to inert no-flag, no-data segments so the engine never sees
    another protocol's bytes."""
    data, dlen, m = tcp.parse_segment(carrier["payload"], carrier["length"],
                                      carrier["meta"])
    meng = dict(m)
    for k in ("src_ip", "src_port", "dst_port", "tcp_flags", "ip_ecn"):
        meng[k] = jnp.where(pred, m[k], jnp.zeros_like(m[k]))
    # drop attribution (soft: the engine rejects internally): a segment
    # with no connection-table match that isn't opening one is what the
    # hardware tile's lookup drops — count it per reason, pre-engine
    c0 = state["conn"]
    hit = ((c0["state"][None, :] != tcp.CLOSED)
           & (c0["remote_ip"][None, :] == meng["src_ip"][:, None])
           & (c0["remote_port"][None, :] == meng["src_port"][:, None])
           & (c0["local_port"][None, :] == meng["dst_port"][:, None])
           ).any(axis=1)
    is_syn = (meng["tcp_flags"] & tcp.SYN) != 0
    carrier["drop_reason"] = jnp.where(
        pred & ~hit & ~is_syn, reasons.TCP_NO_CONN, 0).astype(jnp.int32)
    conn, resps = tcp.rx_batch(state["conn"], data,
                               jnp.where(pred, dlen, 0), meng)
    state = dict(state)
    state["conn"] = conn
    cc = conn.get("cc")
    telem = state.get("telemetry")
    if cc is not None and telem is not None \
            and ccmod.log_name(0) in telem["logs"]:
        # append into the executor's per-run telemetry dict IN PLACE:
        # replacing state["telemetry"] would orphan the dict the executor
        # keeps appending node counter rows into
        rows = ccmod.log_rows(cc, telem["step"])
        for k in range(rows.shape[0]):
            nm = ccmod.log_name(k)
            telem["logs"][nm] = telemetry.append(
                telem["logs"][nm], rows[k:k + 1], jnp.ones((1,), bool))
    carrier.update(meta=m, tcp_resps=resps)
    return state, carrier, None


# ---------------------------------------------------------------------------
# TX protocol tiles


@register_tile("udp_tx")
def udp_tx(state, carrier, pred, ctx):
    """RPC re-framing + UDP build with reply-swapped addressing."""
    m = carrier["meta"]
    q, ql = rpc.build(carrier["out_body"], carrier["out_blen"],
                      m["msg_type"], m["req_id"])
    mtx = dict(m)
    mtx["src_ip"], mtx["dst_ip"] = m["dst_ip"], m["src_ip"]
    mtx["src_port"], mtx["dst_port"] = m["dst_port"], m["src_port"]
    mtx["ip_proto"] = jnp.full_like(m["src_ip"], ipv4.PROTO_UDP)
    q, ql = udp.build(q, ql, mtx)
    carrier.update(tx_payload=q, tx_len=ql, tx_meta=mtx, tx_csum_offset=6)
    return state, carrier, None


@register_tile("tcp_tx")
def tcp_tx(state, carrier, pred, ctx):
    """Build one batch of TCP segments from engine-emitted metadata (the
    wrapper seeds carrier meta from tx_emit's segment fields)."""
    m = carrier["meta"]
    q, ql = tcp.build_segment(
        carrier["payload"], carrier["length"],
        {k: v for k, v in m.items()
         if k in ("src_ip", "dst_ip", "src_port", "dst_port", "tcp_seq",
                  "tcp_ack", "tcp_flags", "tcp_wnd")})
    mtx = dict(m)
    mtx["ip_proto"] = jnp.full((q.shape[0],), ipv4.PROTO_TCP, jnp.uint32)
    carrier.update(tx_payload=q, tx_len=ql, tx_meta=mtx, tx_csum_offset=16)
    return state, carrier, None


@register_tile("nat_tx", init=_nat_init)
def nat_tx(state, carrier, pred, ctx):
    """Physical src -> virtual src on the reply path, with the same
    incremental L4-checksum patch (the client must see a checksum valid
    for the virtual address)."""
    mtx = carrier["tx_meta"]
    old_src = mtx["src_ip"]
    mtx, found = nat_mod.tx(state["nat"], mtx)
    off = carrier.get("tx_csum_offset")
    if off is not None:
        carrier["tx_payload"] = nat_mod.fixup_l4_checksum(
            carrier["tx_payload"], off, old_src, mtx["src_ip"], found,
            zero_is_disabled=(off == 6))       # 0-skip is UDP-only
    carrier["tx_meta"] = mtx
    return state, carrier, None


@register_tile("ipinip_encap")
def ipinip_encap(state, carrier, pred, ctx):
    """Wrap the built packet in an outer IPv4 header toward the physical
    host (the other network-virtualization option, paper §4.5)."""
    q, ql = ipinip.encap(carrier["tx_payload"], carrier["tx_len"],
                         carrier["tx_meta"], ctx.options["outer_src"],
                         ctx.options["outer_dst"])
    carrier.update(tx_payload=q, tx_len=ql, tx_csum_offset=None)
    return state, carrier, None


@register_tile("ip_tx")
def ip_tx(state, carrier, pred, ctx):
    q, ql = ipv4.build(carrier["tx_payload"], carrier["tx_len"],
                       carrier["tx_meta"])
    carrier.update(tx_payload=q, tx_len=ql)
    return state, carrier, None


@register_tile("eth_tx")
def eth_tx(state, carrier, pred, ctx):
    m = carrier["meta"]
    mtx = dict(carrier["tx_meta"])
    mtx["eth_dst_hi"], mtx["eth_dst_lo"] = m["eth_src_hi"], m["eth_src_lo"]
    mtx["eth_src_hi"], mtx["eth_src_lo"] = m["eth_dst_hi"], m["eth_dst_lo"]
    q, ql = eth.build(carrier["tx_payload"], carrier["tx_len"], mtx)
    carrier.update(tx_payload=q, tx_len=ql)
    return state, carrier, None


# ---------------------------------------------------------------------------
# push-mode observability tiles (repro.obs.{postcard,series,slo})
#
# Both are *egress taps*: the tile functions are structural (the postcard
# pack and the watchdog evaluation need the cross-stage enter/exit/visit
# arrays, which only exist once every stage has run, so the executor does
# the work at batch egress — see CompiledPipeline.run).  Registering them
# as real tiles puts them in the route graph, the NoC placement, and the
# deadlock analysis, exactly like the paper's compile-time checks for any
# other element.


@register_tile("int_mirror")
def int_mirror(state, carrier, pred, ctx):
    """Postcard mirror behind eth_tx: for frames selected by the flight
    recorder's runtime sampling knobs, one extra egress frame per sampled
    packet carries the per-hop TLVs to the collector (the executor packs
    ``pc_payload``/``pc_len``/``pc_valid`` at batch egress)."""
    return state, carrier, None


def _watchdog_init(ctx):
    from repro.obs import slo
    p = (ctx.members[0].params or {})
    return {"slo": slo.make_rules(int(p.get("rules", slo.NUM_RULES)))}


@register_tile("watchdog", init=_watchdog_init)
def watchdog(state, carrier, pred, ctx):
    """SLO watchdog behind eth_tx: threshold rules over the series ring
    (``state["slo"]``, set live via OP_SLO_SET) are evaluated by the
    executor at batch egress; alert frames land in
    ``alert_payload``/``alert_len``/``alert_valid``."""
    return state, carrier, None


@register_tile("controller")
def controller(state, carrier, pred, ctx):
    """Control-plane tiles live on the ctrl NoC; on the data path they are
    inert (commands arrive via control.controller_apply)."""
    return state, carrier, None


# ---------------------------------------------------------------------------
# application tiles (direct-attached accelerator compute, paper §5/§6)
#
# These are topology-declared like any protocol tile: the serving topology
# routes udp_rx -> app on `rpc_msg` (the RPC frame's msg_type), so the
# request *kind* — not just the port — picks the tile, and the CAM entry is
# runtime-rewritable like every other keyed route.  Both tiles are pure
# JAX: inside `run_stream` the ingest -> compute -> reply loop runs with
# zero host syncs, the paper's direct-attached path.


def _lm_init(ctx):
    from repro.core.compiler import CompileError
    b = ctx.binding
    if b is None:
        raise CompileError(f"lm_serve tile {ctx.name!r} has no LmTileDecl "
                           f"binding")
    # fresh buffers per init_state (see _app_init: donation safety)
    fresh = jax.tree_util.tree_map(lambda x: jnp.array(x), b.state)
    return {"apps": {ctx.name: fresh}}


@register_tile("lm_serve", init=_lm_init)
def lm_serve(state, carrier, pred, ctx):
    """Direct-attached LM decode: session/KV state lives in the stack
    state (the run_stream scan carry); each arriving MSG_LM_GENERATE
    triggers one on-device decode step for its session and the reply body
    (the generated token) is written in the same device program."""
    from repro.apps import lm_server
    apps = dict(state["apps"])
    st, nb, nl = lm_server.tile_process(ctx.binding, apps[ctx.name],
                                        carrier["body"], carrier["blen"],
                                        pred)
    apps[ctx.name] = st
    state = dict(state)
    state["apps"] = apps
    # soft-drop attribution from the reply's error sentinel (the request
    # was answered with an ERR_*, not served)
    from repro.net import bytesops as B
    n_out = B.be16(nb, 4)
    carrier["drop_reason"] = jnp.where(
        pred & (n_out == lm_server.ERR_BAD_REQUEST), reasons.APP_BAD_REQ,
        jnp.where(pred & (n_out == lm_server.ERR_NO_SESSION),
                  reasons.APP_NO_SESSION,
                  jnp.where(pred & (n_out == lm_server.ERR_NO_SLOT),
                            reasons.APP_NO_SLOT, 0))).astype(jnp.int32)
    carrier["out_body"] = jnp.where(pred[:, None], nb, carrier["out_body"])
    carrier["out_blen"] = jnp.where(pred, nl, carrier["out_blen"])
    info = dict(carrier["info"])
    info[ctx.name] = pred
    carrier["info"] = info
    return state, carrier, None


def _rs_serve_init(ctx):
    return {"apps": {ctx.name: {
        "ops": jnp.zeros((), jnp.int32),
        "bytes": jnp.zeros((), jnp.int32)}}}


@register_tile("rs_serve", init=_rs_serve_init)
def rs_serve(state, carrier, pred, ctx):
    """Direct-attached RS(8,2) encode (kernels/rs_encode) keyed on
    MSG_RS_ENCODE: 4 KiB data in, 1 KiB parity out, computed on device.
    Set ``params={"use_pallas": True}`` on the TileDecl for the Pallas
    kernel.  Needs the batch payload wide enough for a 4 KiB body; on a
    narrower arena the tile serves nothing (requests get ERR via blen 0)."""
    from repro.apps import reed_solomon as RS
    from repro.kernels.rs_encode import ops as rs_ops
    body, blen = carrier["body"], carrier["blen"]
    n = body.shape[0]
    use_pallas = bool(ctx.members[0].params.get("use_pallas", False))
    info = dict(carrier["info"])
    if body.shape[1] < RS.REQ:                 # arena too narrow: no-serve
        info[ctx.name] = jnp.zeros((n,), bool)
        carrier["info"] = info
        carrier["out_blen"] = jnp.where(pred, 0, carrier["out_blen"])
        carrier["drop_reason"] = jnp.where(
            pred, reasons.APP_BAD_REQ, 0).astype(jnp.int32)
        return state, carrier, None
    valid = pred & (blen >= RS.REQ)
    carrier["drop_reason"] = jnp.where(
        pred & ~valid, reasons.APP_BAD_REQ, 0).astype(jnp.int32)

    def encode(data):
        parity = rs_ops.encode_blocks(data, k=RS.K, p=RS.P,
                                      use_pallas=use_pallas)
        out = jnp.zeros_like(body)
        return out.at[:, :RS.RESP].set(parity)

    out = jax.lax.cond(valid.any(), encode,
                       lambda d: jnp.zeros_like(body), body[:, :RS.REQ])
    carrier["out_body"] = jnp.where(valid[:, None], out,
                                    carrier["out_body"])
    carrier["out_blen"] = jnp.where(valid, RS.RESP,
                                    jnp.where(pred, 0, carrier["out_blen"]))
    apps = dict(state["apps"])
    a = dict(apps[ctx.name])
    a["ops"] = a["ops"] + valid.sum(dtype=jnp.int32)
    a["bytes"] = a["bytes"] + jnp.where(valid, RS.REQ, 0).sum(dtype=jnp.int32)
    apps[ctx.name] = a
    state = dict(state)
    state["apps"] = apps
    info[ctx.name] = valid
    carrier["info"] = info
    return state, carrier, None
