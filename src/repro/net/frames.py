"""numpy-side golden frame builders — the 'unmodified Linux client'.

Benchmarks and tests build wire-format Ethernet/IPv4/UDP/TCP frames here
(host side) and feed them to the JAX stack, proving standard-protocol
interop without touching the device path.
"""
from __future__ import annotations

import struct

import numpy as np

from repro.net.bytesops import np_checksum16


def eth_frame(dst_mac: bytes, src_mac: bytes, ethertype: int,
              payload: bytes, vlan: int = None) -> bytes:
    if vlan is None:
        return dst_mac + src_mac + struct.pack("!H", ethertype) + payload
    return (dst_mac + src_mac + struct.pack("!HH", 0x8100, vlan)
            + struct.pack("!H", ethertype) + payload)


def ipv4_packet(src_ip: int, dst_ip: int, proto: int, payload: bytes,
                ttl: int = 64, ident: int = 0) -> bytes:
    total = 20 + len(payload)
    hdr = struct.pack("!BBHHHBBH", 0x45, 0, total, ident, 0x4000, ttl,
                      proto, 0) + struct.pack("!II", src_ip, dst_ip)
    csum = np_checksum16(hdr)
    hdr = hdr[:10] + struct.pack("!H", csum) + hdr[12:]
    return hdr + payload


def udp_datagram(src_ip: int, dst_ip: int, src_port: int, dst_port: int,
                 payload: bytes, with_checksum: bool = True) -> bytes:
    ulen = 8 + len(payload)
    hdr = struct.pack("!HHHH", src_port, dst_port, ulen, 0)
    if with_checksum:
        pseudo = struct.pack("!IIBBH", src_ip, dst_ip, 0, 17, ulen)
        csum = np_checksum16(pseudo + hdr + payload)
        csum = csum or 0xFFFF
        hdr = hdr[:6] + struct.pack("!H", csum)
    return hdr + payload


TCP_FIN, TCP_SYN, TCP_RST, TCP_PSH, TCP_ACK = 0x01, 0x02, 0x04, 0x08, 0x10


def tcp_segment(src_ip: int, dst_ip: int, src_port: int, dst_port: int,
                seq: int, ack: int, flags: int, payload: bytes = b"",
                window: int = 65535) -> bytes:
    hdr = struct.pack("!HHIIBBHHH", src_port, dst_port, seq & 0xFFFFFFFF,
                      ack & 0xFFFFFFFF, 5 << 4, flags, window, 0, 0)
    tlen = len(hdr) + len(payload)
    pseudo = struct.pack("!IIBBH", src_ip, dst_ip, 0, 6, tlen)
    csum = np_checksum16(pseudo + hdr + payload)
    hdr = hdr[:16] + struct.pack("!H", csum) + hdr[18:]
    return hdr + payload


def udp_rpc_frame(src_ip, dst_ip, src_port, dst_port, payload: bytes,
                  dst_mac=b"\x02\x00\x00\x00\x00\x01",
                  src_mac=b"\x02\x00\x00\x00\x00\x02",
                  vlan=None) -> bytes:
    dgram = udp_datagram(src_ip, dst_ip, src_port, dst_port, payload)
    pkt = ipv4_packet(src_ip, dst_ip, 17, dgram)
    return eth_frame(dst_mac, src_mac, 0x0800, pkt, vlan=vlan)


def tcp_eth_frame(src_ip, dst_ip, src_port, dst_port, seq, ack, flags,
                  payload: bytes = b"", window: int = 65535,
                  dst_mac=b"\x02\x00\x00\x00\x00\x01",
                  src_mac=b"\x02\x00\x00\x00\x00\x02") -> bytes:
    seg = tcp_segment(src_ip, dst_ip, src_port, dst_port, seq, ack, flags,
                      payload, window)
    pkt = ipv4_packet(src_ip, dst_ip, 6, seg)
    return eth_frame(dst_mac, src_mac, 0x0800, pkt)


def to_batch(frames, max_len: int = None):
    """Pack a list of byte strings into (B, L) uint8 + lengths.

    ``max_len=None`` auto-sizes L to the longest frame.  An explicit
    ``max_len`` smaller than a frame raises a ValueError naming the frame
    and both sizes (instead of numpy's opaque broadcast error)."""
    if max_len is None:
        max_len = max((len(f) for f in frames), default=1)
    B = len(frames)
    payload = np.zeros((B, max_len), np.uint8)
    length = np.zeros((B,), np.int32)
    for i, f in enumerate(frames):
        if len(f) > max_len:
            raise ValueError(
                f"frame {i} is {len(f)} bytes but max_len={max_len}; "
                f"pass max_len >= {len(f)} or omit it to auto-size")
        payload[i, :len(f)] = np.frombuffer(f, np.uint8)
        length[i] = len(f)
    return payload, length


class FrameArena:
    """Preallocated multi-batch frame store for the streaming executor:
    ``payload`` is (n_batches, batch, max_len) uint8, ``length`` is
    (n_batches, batch) int32, both filled **in place** — feeding
    `CompiledPipeline.run_stream` never allocates per batch the way a
    per-call :func:`to_batch` does.  Unused rows stay zero-length (they
    flow through the compiled chain as dead packets: no route matches an
    all-zero frame)."""

    def __init__(self, n_batches: int, batch: int, max_len: int):
        self.n_batches = n_batches
        self.batch = batch
        self.max_len = max_len
        self.payload = np.zeros((n_batches, batch, max_len), np.uint8)
        self.length = np.zeros((n_batches, batch), np.int32)

    @classmethod
    def from_buffers(cls, payload: np.ndarray,
                     length: np.ndarray) -> "FrameArena":
        """Wrap existing (n_batches, batch, max_len) / (n_batches, batch)
        buffers as an arena *view* — no copy: filling the view writes the
        parent buffers in place.  This is how `ShardedFrameArena` hands
        out per-shard arenas over one contiguous (S, N, B, L) store."""
        if payload.shape[:2] != length.shape:
            raise ValueError(
                f"payload {payload.shape} and length {length.shape} "
                f"disagree on (n_batches, batch)")
        arena = cls.__new__(cls)
        arena.n_batches, arena.batch, arena.max_len = payload.shape
        arena.payload = payload
        arena.length = length
        return arena

    @property
    def capacity(self) -> int:
        """Total frame slots."""
        return self.n_batches * self.batch

    def clear(self):
        """Zero every slot in place (no reallocation)."""
        self.payload[:] = 0
        self.length[:] = 0

    def fill(self, frames) -> int:
        """Pack a flat list of frames row-major (batch 0 fills first);
        returns the number of batches holding data.  Stale bytes of
        reused slots are cleared so a shorter refill never leaks the
        previous frame's tail."""
        if len(frames) > self.capacity:
            raise ValueError(
                f"{len(frames)} frames exceed the arena's capacity "
                f"{self.capacity} ({self.n_batches} batches x "
                f"{self.batch} frames)")
        self.clear()
        for i, f in enumerate(frames):
            if len(f) > self.max_len:
                raise ValueError(
                    f"frame {i} is {len(f)} bytes but the arena's "
                    f"max_len is {self.max_len}")
            b, k = divmod(i, self.batch)
            self.payload[b, k, :len(f)] = np.frombuffer(f, np.uint8)
            self.length[b, k] = len(f)
        return -(-len(frames) // self.batch) if frames else 0


def ip(a: str) -> int:
    parts = [int(x) for x in a.split(".")]
    return (parts[0] << 24) | (parts[1] << 16) | (parts[2] << 8) | parts[3]


def l2_offset(frame: bytes) -> int:
    """Where the IPv4 header starts: 0 for an IP-level frame, 14 for
    Ethernet.  Frames may be either (the TCP stack's TX boundary emits IP
    frames): an IP-level frame starts with an IPv4 version nibble AND its
    total-length field covers the whole frame — an Ethernet frame carries
    14 extra bytes, so a MAC that happens to start with 0x4_ cannot
    satisfy both."""
    is_ip = (frame[0] >> 4 == 4
             and struct.unpack_from("!H", frame, 2)[0] == len(frame))
    return 0 if is_ip else 14
