"""Minimal RPC framing used by the app tiles (echo / RS / VR / LM serving).

Frame layout (big-endian):
  [magic u16 = 0xBEE5][msg_type u8][req_id u32][payload_len u16][payload]

Unmodified clients build these frames over standard UDP or TCP sockets
(frames.py provides the host-side builders).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B

MAGIC = 0xBEE5
HLEN = 9

MSG_ECHO = 1
MSG_RS_ENCODE = 2
MSG_VR_PREPARE = 3
MSG_VR_COMMIT = 4
MSG_LM_GENERATE = 5
MSG_CTRL = 6
MSG_LM_RELEASE = 7
MSG_ALERT = 8          # watchdog -> collector: SLO threshold edge
MSG_POSTCARD = 9       # int_mirror -> collector: per-hop telemetry


def parse(payload, length):
    return parse_ex(payload, length)[:4]


def parse_ex(payload, length):
    """`parse` plus a per-packet drop-reason code (repro.obs.reasons)."""
    from repro.obs import reasons as R
    magic = B.be16(payload, 0)
    msg_type = B.u8(payload, 2)
    req_id = B.be32(payload, 3)
    plen = B.be16(payload, 7)
    ok_magic = magic == MAGIC
    ok_len = plen.astype(jnp.int32) + HLEN <= length
    ok = ok_magic & ok_len
    reason = jnp.where(~ok_magic, R.RPC_MAGIC,
                       jnp.where(~ok_len, R.RPC_LEN, R.NONE))
    body = B.shift_left(payload, HLEN)
    return (body, plen.astype(jnp.int32),
            {"msg_type": msg_type, "req_id": req_id}, ok,
            reason.astype(jnp.int32))


def build(payload, length, msg_type, req_id):
    out = B.shift_right(payload, HLEN)
    u = jnp.asarray
    B_ = payload.shape[0]
    out = B.set_be16(out, 0, jnp.full((B_,), MAGIC, jnp.uint32))
    out = B.set_u8(out, 2, jnp.broadcast_to(jnp.uint32(msg_type), (B_,))
                   if not hasattr(msg_type, "shape") else msg_type)
    out = B.set_be32(out, 3, req_id)
    out = B.set_be16(out, 7, length.astype(jnp.uint32))
    return out, length + HLEN


def np_frame(msg_type: int, req_id: int, payload: bytes) -> bytes:
    import struct
    return struct.pack("!HBIH", MAGIC, msg_type, req_id, len(payload)) + payload
