"""Ethernet tile: parse/strip on RX (VLAN-aware, paper §4.2), build on TX."""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B

ETH_HLEN = 14
VLAN_HLEN = 18
ETHERTYPE_IPV4 = 0x0800
ETHERTYPE_VLAN = 0x8100


def parse(payload, length):
    """Returns (stripped_payload, new_length, meta) — meta holds the MACs
    (hi32/lo16 words), the real ethertype, and the VLAN tag if present."""
    dst_hi = B.be32(payload, 0)
    dst_lo = B.be16(payload, 4)
    src_hi = B.be32(payload, 6)
    src_lo = B.be16(payload, 10)
    etype = B.be16(payload, 12)
    is_vlan = etype == ETHERTYPE_VLAN
    vlan_tci = jnp.where(is_vlan, B.be16(payload, 14), 0)
    real_etype = jnp.where(is_vlan, B.be16(payload, 16), etype)
    hlen = jnp.where(is_vlan, VLAN_HLEN, ETH_HLEN).astype(jnp.int32)
    stripped = B.shift_left(payload, hlen)
    meta = {
        "eth_dst_hi": dst_hi, "eth_dst_lo": dst_lo,
        "eth_src_hi": src_hi, "eth_src_lo": src_lo,
        "ethertype": real_etype, "vlan_tci": vlan_tci,
    }
    return stripped, length - hlen, meta


def build(payload, length, meta):
    """Prepend an Ethernet header; TX swaps src/dst (reply semantics are the
    caller's job — these fields come straight from meta)."""
    out = B.shift_right(payload, ETH_HLEN)
    out = B.set_be32(out, 0, meta["eth_dst_hi"])
    out = B.set_be16(out, 4, meta["eth_dst_lo"])
    out = B.set_be32(out, 6, meta["eth_src_hi"])
    out = B.set_be16(out, 10, meta["eth_src_lo"])
    out = B.set_be16(out, 12, meta["ethertype"])
    return out, length + ETH_HLEN
