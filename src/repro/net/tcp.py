"""Server-side TCP engine (paper §4.4).

Scope matches the paper's prototype: accepts connections (3-way handshake),
generates sequence/ACK numbers, window-based flow control, fast retransmit
on 3 dup-ACKs, and timer retransmit.  No SACK, no active open (documented
paper limitations).  RX and TX share state, mirroring the paper's
dedicated-wire coupling of the TCP RX/TX tiles.

Congestion control — the paper's other stated limitation — is supplied by
:mod:`repro.transport.cc` and is *optional*: pass ``cc_policy=`` to
:func:`init` (the ``tcp_rx`` tile parameter does this in compiled stacks)
and the connection table gains a nested ``conn["cc"]`` block of per-conn
arrays (cwnd/ssthresh/RTT estimator/recovery state).  Without it the
engine is bit-identical to the seed prototype.  With it, ``tx_emit``
gates on min(cwnd, peer window), ACK processing drives NewReno or
DCTCP-style ECN, ``tick`` runs the adaptive RTO, and the engine echoes
ECE on acks for CE-marked segments.

The engine is a connection *table* — all state is fixed-shape arrays, so a
connection can be serialized / reinstalled for live migration (paper §6.7)
and the control plane can inspect any field.

Stream model: rx_buf / tx_buf are linear per-connection byte buffers.
Out-of-order segments are dropped (the sender's fast-retransmit recovers),
which is exactly the dup-ACK behavior the paper's engine relies on.

App interface (paper §4.4): the application asks to be notified when N rx
bytes are available (`app_readable`), then reads them (`app_read`); on TX
it requests buffer space (`app_tx_space`), copies data (`app_send`), and
the engine emits segments within the peer window (`tx_emit`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.net import bytesops as B
from repro.transport import cc as ccmod

CLOSED, SYN_RCVD, ESTABLISHED = 0, 1, 2
TCP_HLEN = 20
FIN, SYN, RST, PSH, ACK = 0x01, 0x02, 0x04, 0x08, 0x10
ECE, CWR = 0x40, 0x80                     # RFC 3168 echo bits

U32 = jnp.uint32


def _u32(x):
    return jnp.asarray(x).astype(U32)


def _seq_lt(a, b):
    """Wrap-safe sequence-space a < b."""
    return ((a - b) >> 31) != 0


def init(max_conns: int = 16, rx_buf: int = 4096, tx_buf: int = 4096,
         local_ip: int = 0x0A000001, cc_policy=None, mss: int = 1460):
    """Connection-table state.  ``cc_policy`` ("newreno" | "dctcp" | None)
    attaches the congestion-control block; None keeps the seed engine."""
    C = max_conns
    z32 = jnp.zeros((C,), U32)
    zi = jnp.zeros((C,), jnp.int32)
    conn = {
        "state": zi, "remote_ip": z32, "remote_port": z32,
        "local_port": z32, "rcv_nxt": z32, "snd_nxt": z32, "snd_una": z32,
        # snd_max = highest sequence ever sent: go-back-N rolls snd_nxt
        # back, but ACKs for data sent before the rollback stay acceptable
        "snd_max": z32,
        "snd_wnd": z32 + 65535, "dup_acks": zi, "retx_timer": zi,
        "iss": z32, "irs": z32,
        "rx_buf": jnp.zeros((C, rx_buf), jnp.uint8),
        "rx_base": z32, "rx_read": zi,
        "tx_buf": jnp.zeros((C, tx_buf), jnp.uint8),
        "tx_staged": zi,
        "local_ip": _u32(local_ip),
        "accepts": jnp.zeros((), jnp.int32),   # completed handshakes
    }
    if cc_policy is not None:
        conn["cc"] = ccmod.init(C, mss=mss, policy=cc_policy)
    return conn


# ---------------------------------------------------------------------------
# segment parse/build


def parse_segment(payload, length, meta):
    """TCP header parse (after IP strip).  Returns (data, dlen, meta')."""
    src_port = B.be16(payload, 0)
    dst_port = B.be16(payload, 2)
    seq = B.be32(payload, 4)
    ack = B.be32(payload, 8)
    off_flags = B.be16(payload, 12)
    doff = ((off_flags >> 12) & 0xF).astype(jnp.int32) * 4
    flags = off_flags & 0xFF              # low byte incl. ECE/CWR echoes
    wnd = B.be16(payload, 14)
    data = B.shift_left(payload, doff)
    m = dict(meta)
    m.update({"src_port": src_port, "dst_port": dst_port, "tcp_seq": seq,
              "tcp_ack": ack, "tcp_flags": flags, "tcp_wnd": wnd})
    return data, length - doff, m


def build_segment(payload, length, meta, with_checksum: bool = True):
    """Prepend a 20-byte TCP header (meta fields are reply-oriented)."""
    out = B.shift_right(payload, TCP_HLEN)
    out = B.set_be16(out, 0, meta["src_port"])
    out = B.set_be16(out, 2, meta["dst_port"])
    out = B.set_be32(out, 4, meta["tcp_seq"])
    out = B.set_be32(out, 8, meta["tcp_ack"])
    out = B.set_be16(out, 12, (jnp.full_like(meta["src_port"], 5 << 12)
                               | meta["tcp_flags"]))
    out = B.set_be16(out, 14, meta["tcp_wnd"])
    out = B.set_be16(out, 16, jnp.zeros_like(meta["src_port"]))
    out = B.set_be16(out, 18, jnp.zeros_like(meta["src_port"]))
    tlen = (length + TCP_HLEN).astype(U32)
    if with_checksum:
        pseudo = B.pseudo_header_sum(meta["src_ip"], meta["dst_ip"],
                                     jnp.full_like(meta["src_ip"], 6), tlen)
        csum = B.checksum16_with_pseudo(out, 0, tlen.astype(jnp.int32), pseudo)
        out = B.set_be16(out, 16, csum)
    return out, length + TCP_HLEN


# ---------------------------------------------------------------------------
# connection lookup / allocation


def _lookup(conn, remote_ip, remote_port, local_port):
    match = ((conn["remote_ip"] == remote_ip)
             & (conn["remote_port"] == remote_port)
             & (conn["local_port"] == local_port)
             & (conn["state"] > CLOSED))
    found = match.any()
    idx = jnp.argmax(match)
    return idx, found


def _alloc(conn):
    free = conn["state"] == CLOSED
    return jnp.argmax(free), free.any()


# ---------------------------------------------------------------------------
# RX: process one segment (scalars) against the table


def rx_one(conn: Dict, seg: Dict, data_row, dlen):
    """seg: scalar meta (src_ip, src_port, dst_port, tcp_seq, tcp_ack,
    tcp_flags, tcp_wnd).  Returns (conn', resp) where resp is a dict of
    scalar reply fields (resp["emit"] False = no reply)."""
    flags = seg["tcp_flags"]
    is_syn = (flags & SYN) != 0
    is_ack = (flags & ACK) != 0
    is_fin = (flags & FIN) != 0
    is_rst = (flags & RST) != 0

    idx, found = _lookup(conn, seg["src_ip"], seg["src_port"],
                         seg["dst_port"])
    slot, has_free = _alloc(conn)
    new_conn = is_syn & ~found & has_free
    i = jnp.where(new_conn, slot, idx)
    act = found | new_conn            # packet maps to a connection

    st = conn["state"][i]
    iss = jnp.where(new_conn,
                    (seg["tcp_seq"] * U32(2654435761) + U32(12345)),
                    conn["iss"][i])
    irs = jnp.where(new_conn, seg["tcp_seq"], conn["irs"][i])

    # ---- handshake ------------------------------------------------------
    do_synack = new_conn | (is_syn & found & (st == SYN_RCVD))
    established = (st == SYN_RCVD) & is_ack & ~is_syn & \
        (seg["tcp_ack"] == iss + 1)

    # ---- ACK processing (flow control + fast retransmit) -----------------
    snd_una = conn["snd_una"][i]
    snd_nxt = conn["snd_nxt"][i]
    snd_max = conn["snd_max"][i]
    ack_ok = is_ack & (st == ESTABLISHED)
    # acceptable ACKs cover anything ever sent (snd_una, snd_max] — after
    # a go-back-N rollback snd_nxt may sit below in-flight ACKs
    advanced = ack_ok & _seq_lt(snd_una, seg["tcp_ack"]) \
        & ~_seq_lt(snd_max, seg["tcp_ack"])
    new_una = jnp.where(advanced, seg["tcp_ack"], snd_una)
    # handshake completion acknowledges our SYN: snd_una := iss+1
    new_una = jnp.where(established, seg["tcp_ack"], new_una)
    # an ACK past a rolled-back snd_nxt also re-advances transmission
    snd_nxt = jnp.where(advanced & _seq_lt(snd_nxt, seg["tcp_ack"]),
                        seg["tcp_ack"], snd_nxt)
    dup = ack_ok & (seg["tcp_ack"] == snd_una) & (dlen == 0) & \
        (snd_max != snd_una)
    dup_acks = jnp.where(advanced, 0,
                         conn["dup_acks"][i] + dup.astype(jnp.int32))
    # fire on exactly the third duplicate (RFC 5681) and keep counting:
    # re-arming only on an advancing ACK stops the same loss event's
    # trailing dup-ACKs from re-triggering retransmission every 3
    fast_retx = dup & (dup_acks == 3)

    # ---- congestion control (repro.transport.cc, optional) ---------------
    cc = conn.get("cc")
    ece_echo = jnp.zeros((), bool)
    partial = jnp.zeros((), bool)
    if cc is not None:
        ece = (flags & ECE) != 0
        acked = jnp.where(advanced, seg["tcp_ack"] - snd_una, U32(0))
        cc, exit_rec, partial = ccmod.on_ack(
            cc, i, est=act & ack_ok, advanced=act & advanced,
            acked=acked, fast_retx=act & fast_retx, ece=ece,
            ack_seq=seg["tcp_ack"], high_seq=snd_max,
            flight=(snd_max - snd_una).astype(jnp.int32))
        # NewReno leaves recovery on the full ACK: dup-ACK counting restarts
        dup_acks = jnp.where(exit_rec, 0, dup_acks)
        # receiver side: echo CE marks back to the peer on our ACKs
        ce = seg.get("ip_ecn", jnp.zeros((), U32)) == 3
        ece_echo = (st == ESTABLISHED) & (dlen > 0) & ce

    # ---- in-order data --------------------------------------------------
    rcv_nxt = jnp.where(new_conn, seg["tcp_seq"] + 1, conn["rcv_nxt"][i])
    in_order = (st == ESTABLISHED) & (dlen > 0) & (seg["tcp_seq"] == rcv_nxt)
    rx_off = (rcv_nxt - conn["rx_base"][i]).astype(jnp.int32)
    RX = conn["rx_buf"].shape[1]
    fits = in_order & (rx_off + dlen <= RX)
    # masked write of data_row into rx_buf[i, rx_off:rx_off+dlen]
    Lrow = data_row.shape[0]
    dst_idx = rx_off + jnp.arange(Lrow)
    wmask = fits & (jnp.arange(Lrow) < dlen)
    row = conn["rx_buf"][i]
    safe_idx = jnp.clip(dst_idx, 0, RX - 1)
    row = row.at[safe_idx].set(jnp.where(wmask, data_row, row[safe_idx]))
    rcv_nxt2 = jnp.where(fits, rcv_nxt + dlen.astype(U32), rcv_nxt)
    rcv_nxt2 = jnp.where(is_fin & (st == ESTABLISHED), rcv_nxt2 + 1,
                         rcv_nxt2)

    # ---- state update ---------------------------------------------------
    new_state = jnp.where(new_conn, SYN_RCVD, st)
    new_state = jnp.where(established, ESTABLISHED, new_state)
    new_state = jnp.where(is_fin & (st == ESTABLISHED), CLOSED, new_state)
    new_state = jnp.where(is_rst & found, CLOSED, new_state)

    upd = lambda a, v: a.at[i].set(jnp.where(act, v, a[i]))
    conn = dict(conn)
    if cc is not None:
        conn["cc"] = cc
    conn["state"] = upd(conn["state"], new_state)
    conn["remote_ip"] = upd(conn["remote_ip"], seg["src_ip"])
    conn["remote_port"] = upd(conn["remote_port"], seg["src_port"])
    conn["local_port"] = upd(conn["local_port"], seg["dst_port"])
    conn["iss"] = upd(conn["iss"], iss)
    conn["irs"] = upd(conn["irs"], irs)
    conn["rcv_nxt"] = upd(conn["rcv_nxt"], rcv_nxt2)
    conn["snd_una"] = upd(conn["snd_una"], jnp.where(new_conn, iss, new_una))
    conn["snd_nxt"] = upd(conn["snd_nxt"],
                          jnp.where(new_conn, iss + 1, snd_nxt))
    conn["snd_max"] = upd(conn["snd_max"],
                          jnp.where(new_conn, iss + 1, snd_max))
    conn["snd_wnd"] = upd(conn["snd_wnd"], seg["tcp_wnd"])
    conn["dup_acks"] = upd(conn["dup_acks"], dup_acks)
    # an advancing ACK restarts the retransmit timer (RFC 6298 5.3) —
    # without this, any transfer longer than the RTO hits a spurious RTO
    conn["retx_timer"] = upd(conn["retx_timer"],
                             jnp.where(advanced, 0, conn["retx_timer"][i]))
    conn["rx_base"] = upd(conn["rx_base"],
                          jnp.where(new_conn, seg["tcp_seq"] + 1,
                                    conn["rx_base"][i]))
    conn["rx_buf"] = conn["rx_buf"].at[i].set(
        jnp.where(act, row, conn["rx_buf"][i]))
    conn["accepts"] = conn["accepts"] + established.astype(jnp.int32)

    # ---- response -------------------------------------------------------
    # SYN-ACK for new conns; pure ACK for data/FIN; nothing for pure ACKs.
    want_ack = fits | (is_fin & (st == ESTABLISHED)) | \
        ((dlen > 0) & (st == ESTABLISHED) & ~in_order)
    emit = act & (do_synack | want_ack)
    resp = {
        "emit": emit,
        # partial ACKs in fast recovery retransmit again (NewReno)
        "fast_retx": act & (fast_retx | partial),
        "conn": i,
        "src_ip": seg["dst_ip"], "dst_ip": seg["src_ip"],
        "src_port": seg["dst_port"], "dst_port": seg["src_port"],
        "tcp_seq": jnp.where(do_synack, iss, conn["snd_nxt"][i]),
        "tcp_ack": rcv_nxt2,
        "tcp_flags": jnp.where(
            do_synack, U32(SYN | ACK),
            U32(ACK) | jnp.where(ece_echo, U32(ECE), U32(0))),
        "tcp_wnd": U32(65535) - (rcv_nxt2 - conn["rx_base"][i]),
        "established": established,
    }
    return conn, resp


def rx_batch(conn: Dict, data, dlen, meta):
    """Sequentially process a batch of parsed segments (order matters)."""
    Bsz = data.shape[0]

    def step(c, xs):
        row, dl, m = xs
        c, resp = rx_one(c, m, row, dl)
        return c, resp

    metas = {k: meta[k] for k in ("src_ip", "dst_ip", "src_port", "dst_port",
                                  "tcp_seq", "tcp_ack", "tcp_flags",
                                  "tcp_wnd")}
    # ECN field rides along for the CC engine (absent in legacy callers)
    metas["ip_ecn"] = meta.get("ip_ecn", jnp.zeros_like(metas["tcp_seq"]))
    conn, resps = jax.lax.scan(step, conn, (data, dlen, metas))
    return conn, resps


# ---------------------------------------------------------------------------
# app interface (paper §4.4 request/notify protocol)


def app_readable(conn, i, n):
    """True when >= n unread in-order bytes are buffered for conn i."""
    avail = (conn["rcv_nxt"][i] - conn["rx_base"][i]).astype(jnp.int32) \
        - conn["rx_read"][i]
    return avail >= n


def app_read(conn, i, n: int):
    """Read n bytes (static size) from the rx stream.  Returns (conn',
    data (n,), ok)."""
    ok = app_readable(conn, i, n)
    off = conn["rx_read"][i]
    data = jax.lax.dynamic_slice(conn["rx_buf"][i], (off,), (n,))
    conn = dict(conn)
    conn["rx_read"] = conn["rx_read"].at[i].add(
        jnp.where(ok, n, 0).astype(jnp.int32))
    return conn, data, ok


def app_tx_space(conn, i):
    TX = conn["tx_buf"].shape[1]
    return TX - conn["tx_staged"][i]


def app_send(conn, i, data, n):
    """Stage n bytes (data: (K,) uint8, n <= K) into the tx buffer."""
    ok = app_tx_space(conn, i) >= n
    off = conn["tx_staged"][i]
    K = data.shape[0]
    TX = conn["tx_buf"].shape[1]
    idx = jnp.clip(off + jnp.arange(K), 0, TX - 1)
    wmask = ok & (jnp.arange(K) < n)
    row = conn["tx_buf"][i]
    row = row.at[idx].set(jnp.where(wmask, data, row[idx]))
    conn = dict(conn)
    conn["tx_buf"] = conn["tx_buf"].at[i].set(row)
    conn["tx_staged"] = conn["tx_staged"].at[i].add(
        jnp.where(ok, n, 0).astype(jnp.int32))
    return conn, ok


def tx_emit(conn, i, mss: int = 1460, retransmit=False):
    """Emit one data segment for conn i from the tx buffer, respecting the
    send window — min(peer window, cwnd) when the CC engine is attached.
    Returns (conn', seg_meta, data (mss,), dlen).

    The two recovery paths are distinct (they used to share one flag):

    * ``retransmit="fast"`` (or True) — fast retransmit: resend exactly
      one MSS from ``snd_una``; ``snd_nxt`` is untouched, so transmission
      resumes where it left off once the hole is filled.
    * ``retransmit="timer"`` — RTO go-back-N restart: resend from
      ``snd_una`` AND roll ``snd_nxt`` back to the end of this segment,
      so subsequent calls re-send the whole outstanding window.
      (``tick`` already rolls ``snd_nxt`` fully back; this mode is for
      drivers that retransmit explicitly without a tick.)
    """
    assert retransmit in (False, True, "fast", "timer"), retransmit
    is_retx = bool(retransmit)
    mode = "fast" if retransmit is True else retransmit
    iss = conn["iss"][i]
    base_seq = iss + 1                       # stream offset 0 in tx_buf
    start = jnp.where(is_retx, conn["snd_una"][i], conn["snd_nxt"][i])
    staged_end = base_seq + conn["tx_staged"][i].astype(U32)
    cc = conn.get("cc")
    wnd_lim = conn["snd_wnd"][i].astype(jnp.int32)
    if cc is not None:
        wnd_lim = ccmod.effective_wnd(cc, i, conn["snd_wnd"][i])
    in_flight = (start - conn["snd_una"][i]).astype(jnp.int32)
    wnd_room = wnd_lim - in_flight
    avail = (staged_end - start).astype(jnp.int32)
    dlen = jnp.clip(jnp.minimum(avail, wnd_room), 0, mss)
    off = (start - base_seq).astype(jnp.int32)
    TX = conn["tx_buf"].shape[1]
    idx = jnp.clip(off + jnp.arange(mss), 0, TX - 1)
    data = jnp.where(jnp.arange(mss) < dlen, conn["tx_buf"][i][idx], 0)
    live = (conn["state"][i] == ESTABLISHED) & (dlen > 0)
    conn = dict(conn)
    if mode == "timer":
        # go-back-N restart: everything past this segment is re-sent
        conn["snd_nxt"] = conn["snd_nxt"].at[i].set(
            jnp.where(live, start + dlen.astype(U32), conn["snd_nxt"][i]))
    elif not is_retx:
        end = start + dlen.astype(U32)
        conn["snd_nxt"] = conn["snd_nxt"].at[i].set(
            jnp.where(live, end, conn["snd_nxt"][i]))
        conn["snd_max"] = conn["snd_max"].at[i].set(
            jnp.where(live & _seq_lt(conn["snd_max"][i], end), end,
                      conn["snd_max"][i]))
        if cc is not None:      # RTT sample only on new data (Karn)
            conn["cc"] = ccmod.stamp_rtt(cc, i, end, live)
    seg = {
        "emit": live,
        "src_ip": conn["local_ip"], "dst_ip": conn["remote_ip"][i],
        "src_port": conn["local_port"][i], "dst_port": conn["remote_port"][i],
        "tcp_seq": start, "tcp_ack": conn["rcv_nxt"][i],
        "tcp_flags": U32(ACK | PSH), "tcp_wnd": U32(65535),
    }
    return conn, seg, data, jnp.where(live, dlen, 0)


def tick(conn, timeout: int = 8):
    """Timer retransmit: bump per-conn timers; expired conns with unacked
    data get snd_nxt rolled back to snd_una (go-back-N).  With the CC
    engine attached the expiry threshold is the per-connection adaptive
    RTO (SRTT + 4*RTTVAR, exponentially backed off) and ``timeout`` is
    ignored; an expiry collapses cwnd to one MSS."""
    unacked = (conn["snd_max"] != conn["snd_una"]) & \
        (conn["state"] == ESTABLISHED)
    timers = jnp.where(unacked, conn["retx_timer"] + 1, 0)
    cc = conn.get("cc")
    conn = dict(conn)
    if cc is None:
        expired = timers >= timeout
    else:
        cc = ccmod.tick_clock(cc)
        expired = timers >= cc["rto"]
        flight = (conn["snd_max"] - conn["snd_una"]).astype(jnp.int32)
        conn["cc"] = ccmod.on_timer(cc, expired, flight)
    conn["retx_timer"] = jnp.where(expired, 0, timers)
    conn["snd_nxt"] = jnp.where(expired, conn["snd_una"], conn["snd_nxt"])
    return conn, expired


# ---------------------------------------------------------------------------
# live migration (paper §6.7): serialize / reinstall one connection


_MIG_FIELDS = ("state", "remote_ip", "remote_port", "local_port", "rcv_nxt",
               "snd_nxt", "snd_una", "snd_max", "snd_wnd", "dup_acks",
               "iss", "irs", "rx_base", "rx_read", "tx_staged")


def serialize_conn(conn, i):
    """Extract connection i as a flat blob dict (device arrays).  The
    congestion-control block travels with the connection: cwnd/RTT
    estimator state survives migration like everything else."""
    blob = {k: conn[k][i] for k in _MIG_FIELDS}
    blob["rx_buf"] = conn["rx_buf"][i]
    blob["tx_buf"] = conn["tx_buf"][i]
    if "cc" in conn:
        blob["cc"] = {k: conn["cc"][k][i] for k in ccmod.PER_CONN}
    return blob


def install_conn(conn, i, blob):
    """Reinstall a serialized connection into slot i of another engine."""
    conn = dict(conn)
    for k in _MIG_FIELDS:
        conn[k] = conn[k].at[i].set(blob[k].astype(conn[k].dtype))
    conn["rx_buf"] = conn["rx_buf"].at[i].set(blob["rx_buf"])
    conn["tx_buf"] = conn["tx_buf"].at[i].set(blob["tx_buf"])
    if "cc" in conn and "cc" in blob:
        cc = dict(conn["cc"])
        for k in ccmod.PER_CONN:
            cc[k] = cc[k].at[i].set(blob["cc"][k].astype(cc[k].dtype))
        conn["cc"] = cc
    return conn
