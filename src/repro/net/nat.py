"""NAT tile (paper §4.5): virtual IP <-> physical IP translation for
network virtualization and TCP live migration.

The translation table is runtime state (control-plane rewritable).  The
tile sits between IP and TCP on both paths (paper §5.3): RX translates
dst (virtual) -> physical, TX translates src (physical) -> virtual, so the
remote client only ever sees the stable virtual address while the backing
connection migrates between accelerators.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.net import bytesops as B

SLOTS = 8


def init(entries=None) -> Dict[str, jnp.ndarray]:
    virt = jnp.zeros((SLOTS,), jnp.uint32)
    phys = jnp.zeros((SLOTS,), jnp.uint32)
    for i, (v, p) in enumerate(entries or []):
        virt = virt.at[i].set(v)
        phys = phys.at[i].set(p)
    return {"virt": virt, "phys": phys}


def _translate(table_from, table_to, addr):
    hit = table_from[None, :] == addr[:, None]
    found = hit.any(axis=1)
    idx = jnp.argmax(hit, axis=1)
    return jnp.where(found, table_to[idx], addr), found


def rx(nat: Dict, meta: Dict) -> Tuple[Dict, jnp.ndarray]:
    """virtual dst -> physical dst.  Returns (meta', translated_mask)."""
    new_dst, found = _translate(nat["virt"], nat["phys"], meta["dst_ip"])
    m = dict(meta)
    m["dst_ip"] = new_dst
    return m, found


def tx(nat: Dict, meta: Dict) -> Tuple[Dict, jnp.ndarray]:
    """physical src -> virtual src."""
    new_src, found = _translate(nat["phys"], nat["virt"], meta["src_ip"])
    m = dict(meta)
    m["src_ip"] = new_src
    return m, found


def update(nat: Dict, slot, virt_ip, phys_ip) -> Dict:
    """Control-plane rewrite (used during live migration)."""
    return {"virt": nat["virt"].at[slot].set(jnp.uint32(virt_ip)),
            "phys": nat["phys"].at[slot].set(jnp.uint32(phys_ip))}


def fixup_l4_checksum(payload, csum_off: int, old_ip, new_ip, mask,
                      zero_is_disabled: bool = True):
    """Incremental one's-complement checksum update (RFC 1624) after an IP
    rewrite: HC' = ~(~HC + ~m + m') over the changed 16-bit words.

    Rewriting an address invalidates the TCP/UDP checksum (its pseudo
    header covers src/dst IP); real NATs patch it in place rather than
    recompute — so do we, which keeps the NAT tile independent of where it
    sits in the chain.  `csum_off` is the checksum's byte offset within
    `payload` (UDP: 6, TCP: 16).  Rows where `mask` is False pass through
    untouched; `zero_is_disabled` additionally skips checksum 0, which is
    RFC 768's "no checksum" sentinel — a UDP-only rule (for TCP, 0 is a
    legitimate checksum and must still be patched)."""
    csum = B.be16(payload, csum_off).astype(jnp.uint32)
    old_ip = old_ip.astype(jnp.uint32)
    new_ip = new_ip.astype(jnp.uint32)
    s = (~csum & 0xFFFF)
    s = s + (~(old_ip >> 16) & 0xFFFF) + (~old_ip & 0xFFFF)
    s = s + (new_ip >> 16) + (new_ip & 0xFFFF)
    s = (s & 0xFFFF) + (s >> 16)
    s = (s & 0xFFFF) + (s >> 16)
    fixed = ~s & 0xFFFF
    if zero_is_disabled:
        # UDP: never *emit* 0 either (it would read as "no checksum" and
        # disable verification downstream) — same 0 -> 0xFFFF mapping as a
        # full recompute in udp.build
        fixed = jnp.where(fixed == 0, jnp.uint32(0xFFFF), fixed)
        mask = mask & (csum != 0)
    out = jnp.where(mask, fixed, csum)
    return B.set_be16(payload, csum_off, out)
