"""NAT tile (paper §4.5): virtual IP <-> physical IP translation for
network virtualization and TCP live migration.

The translation table is runtime state (control-plane rewritable).  The
tile sits between IP and TCP on both paths (paper §5.3): RX translates
dst (virtual) -> physical, TX translates src (physical) -> virtual, so the
remote client only ever sees the stable virtual address while the backing
connection migrates between accelerators.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

SLOTS = 8


def init(entries=None) -> Dict[str, jnp.ndarray]:
    virt = jnp.zeros((SLOTS,), jnp.uint32)
    phys = jnp.zeros((SLOTS,), jnp.uint32)
    for i, (v, p) in enumerate(entries or []):
        virt = virt.at[i].set(v)
        phys = phys.at[i].set(p)
    return {"virt": virt, "phys": phys}


def _translate(table_from, table_to, addr):
    hit = table_from[None, :] == addr[:, None]
    found = hit.any(axis=1)
    idx = jnp.argmax(hit, axis=1)
    return jnp.where(found, table_to[idx], addr), found


def rx(nat: Dict, meta: Dict) -> Tuple[Dict, jnp.ndarray]:
    """virtual dst -> physical dst.  Returns (meta', translated_mask)."""
    new_dst, found = _translate(nat["virt"], nat["phys"], meta["dst_ip"])
    m = dict(meta)
    m["dst_ip"] = new_dst
    return m, found


def tx(nat: Dict, meta: Dict) -> Tuple[Dict, jnp.ndarray]:
    """physical src -> virtual src."""
    new_src, found = _translate(nat["phys"], nat["virt"], meta["src_ip"])
    m = dict(meta)
    m["src_ip"] = new_src
    return m, found


def update(nat: Dict, slot, virt_ip, phys_ip) -> Dict:
    """Control-plane rewrite (used during live migration)."""
    return {"virt": nat["virt"].at[slot].set(jnp.uint32(virt_ip)),
            "phys": nat["phys"].at[slot].set(jnp.uint32(phys_ip))}
