"""IPv4 tile: parse + checksum verify on RX, build + checksum on TX.
No fragmentation support — internal datacenter services (paper §4.2)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B

IP_HLEN = 20          # options unsupported (ihl=5), like the paper's tile
PROTO_TCP = 6
PROTO_UDP = 17


def parse(payload, length):
    """Returns (stripped, new_length, meta, ok).  ok=False -> drop."""
    return parse_ex(payload, length)[:4]


def parse_ex(payload, length):
    """`parse` plus a per-packet drop-reason code (repro.obs.reasons):
    why ok is False, first failing check wins.  0 = not dropped."""
    from repro.obs import reasons as R
    ver_ihl = B.u8(payload, 0)
    version = ver_ihl >> 4
    ihl = (ver_ihl & 0xF).astype(jnp.int32) * 4
    ecn = B.u8(payload, 1) & 0x3          # RFC 3168 ECN field (3 = CE)
    total_len = B.be16(payload, 2)
    ttl = B.u8(payload, 8)
    proto = B.u8(payload, 9)
    src_ip = B.be32(payload, 12)
    dst_ip = B.be32(payload, 16)
    csum = B.checksum16(payload, 0, ihl)   # over header; valid iff == 0
    ok_ver = version == 4
    ok_csum = csum == 0
    ok_ttl = ttl > 0
    ok_len = total_len.astype(jnp.int32) <= length
    ok = ok_ver & ok_csum & ok_ttl & ok_len
    reason = jnp.where(
        ~ok_ver, R.IP_VERSION,
        jnp.where(~ok_csum, R.IP_CSUM,
                  jnp.where(~ok_ttl, R.IP_TTL,
                            jnp.where(~ok_len, R.IP_LEN, R.NONE))))
    stripped = B.shift_left(payload, ihl)
    meta = {"ip_proto": proto, "src_ip": src_ip, "dst_ip": dst_ip,
            "ip_ttl": ttl, "ip_total_len": total_len, "ip_ecn": ecn}
    return (stripped, total_len.astype(jnp.int32) - ihl, meta, ok,
            reason.astype(jnp.int32))


def build(payload, length, meta, ident=None):
    """Prepend a 20-byte IPv4 header with computed checksum."""
    out = B.shift_right(payload, IP_HLEN)
    total = (length + IP_HLEN).astype(jnp.uint32)
    z = jnp.zeros_like(total)
    out = B.set_u8(out, 0, jnp.full_like(total, 0x45))       # v4, ihl=5
    out = B.set_u8(out, 1, z)                                # dscp
    out = B.set_be16(out, 2, total)
    out = B.set_be16(out, 4, ident if ident is not None else z)  # id
    out = B.set_be16(out, 6, jnp.full_like(total, 0x4000))   # DF
    out = B.set_u8(out, 8, jnp.full_like(total, 64))         # ttl
    out = B.set_u8(out, 9, meta["ip_proto"])
    out = B.set_be16(out, 10, z)                             # csum slot
    out = B.set_be32(out, 12, meta["src_ip"])
    out = B.set_be32(out, 16, meta["dst_ip"])
    csum = B.checksum16(out, 0, jnp.full_like(total, IP_HLEN).astype(jnp.int32))
    out = B.set_be16(out, 10, csum)
    return out, length + IP_HLEN
