"""Cross-device scale-out of the compiled pipeline (paper §5 scale-out).

Composes the two parallelism layers of the sharded dataplane:

  * **intra-device**: RSS replica groups (`core.scaleout.replicate`) fan
    hot tiles out into batched lanes *inside* each shard's compiled scan;
  * **cross-device**: `ShardedStream` wraps `run_stream` in `shard_map`
    over the ``("data",)`` axis of a `launch.mesh.make_mesh_for` mesh, so
    S devices each stream their own row-partition of the frame arena.

Flows are partitioned at the arena-fill boundary — the host-side RSS a
ToR switch or NIC would perform — so shards never exchange traffic and
the per-shard scan lowers with ZERO collectives.  The no-collective /
no-host-callback certificates are checked by ``benchmarks/bench_shard.py``;
per-flow egress is bit-identical to the unsharded reference because each
shard runs the *same* compiled pipeline over the same frames it would see
behind a real RSS front end.

Per-shard management stays in-band: `ShardedConsole` slices one shard's
state view, drives the ordinary `MgmtConsole` against it (LOG_READ /
DROP_READ / GROUP_READ / drain_replica all address that shard's device
tables), and scatters the updated state back.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.compat import shard_map
from repro.launch.mesh import make_mesh_for
from repro.net.frames import FrameArena
from repro.sharding import Policy


class ShardedFrameArena:
    """(S, n_batches, batch, max_len) frame store with per-shard
    :class:`FrameArena` views.  The views alias the parent buffers, so
    per-shard `fill` writes land in the one contiguous array that feeds
    `ShardedStream.run_stream` — no per-shard copies."""

    def __init__(self, shards: int, n_batches: int, batch: int,
                 max_len: int):
        self.shards = shards
        self.n_batches = n_batches
        self.batch = batch
        self.max_len = max_len
        self.payload = np.zeros((shards, n_batches, batch, max_len),
                                np.uint8)
        self.length = np.zeros((shards, n_batches, batch), np.int32)
        self._views = [FrameArena.from_buffers(self.payload[s],
                                               self.length[s])
                       for s in range(shards)]

    def shard(self, s: int) -> FrameArena:
        """Shard ``s``'s arena view (writes go to the parent buffers)."""
        return self._views[s]

    @property
    def capacity(self) -> int:
        return self.shards * self.n_batches * self.batch

    def clear(self):
        self.payload[:] = 0
        self.length[:] = 0

    def fill_shards(self, frames_per_shard: Sequence[Sequence[bytes]]):
        """Fill each shard from its own frame list (pre-partitioned)."""
        if len(frames_per_shard) != self.shards:
            raise ValueError(
                f"{len(frames_per_shard)} frame lists for "
                f"{self.shards} shards")
        self.clear()
        for s, frames in enumerate(frames_per_shard):
            self._views[s].fill(list(frames))

    def fill_rss(self, flows: Dict[int, Sequence[bytes]]):
        """Host-side RSS: partition whole *flows* across shards —
        ``flows`` maps a flow key (e.g. the client port) to that flow's
        frames, and every frame of a flow lands on ``key % shards`` so
        per-flow ordering survives the split, exactly like a hardware
        hash front end.  Returns the per-shard frame counts."""
        per: List[List[bytes]] = [[] for _ in range(self.shards)]
        for key, frames in flows.items():
            per[key % self.shards].extend(frames)
        self.fill_shards(per)
        return [len(p) for p in per]


class ShardedStream:
    """`shard_map` wrapper of a stack's :meth:`run_stream` over the
    ``("data",)`` mesh axis.  State, arena, and outputs all carry a
    leading shard axis; inside each shard the axis has extent 1 and is
    squeezed away, so the per-shard program is the *unmodified* compiled
    pipeline — replica groups, mgmt commits, telemetry and all."""

    def __init__(self, stack, shards: Optional[int] = None, mesh=None):
        self.stack = stack
        self.shards = shards if shards is not None else len(jax.devices())
        self.mesh = mesh if mesh is not None else make_mesh_for(
            self.shards, model_parallel=1)
        self.policy = Policy(dp=("data",), enabled=True)
        spec = self.policy.batch()

        def body(state, payloads, lengths):
            st = jax.tree.map(lambda x: x[0], state)
            st, outs = stack.run_stream(st, payloads[0], lengths[0])
            return (jax.tree.map(lambda x: x[None], st),
                    jax.tree.map(lambda x: x[None], outs))

        self._sharded = shard_map(body, mesh=self.mesh,
                                  in_specs=(spec, spec, spec),
                                  out_specs=(spec, spec))

    def init_state(self):
        """One replica of the stack state per shard (leading S axis)."""
        st = self.stack.init_state()
        return jax.tree.map(
            lambda x: jnp.stack([x] * self.shards), st)

    def make_arena(self, n_batches: int, batch: int,
                   max_len: int) -> ShardedFrameArena:
        return ShardedFrameArena(self.shards, n_batches, batch, max_len)

    def run_stream(self, state, payloads, lengths):
        """All shards stream their (N, B, L) partition under one
        dispatch.  Returns (state', outs) with leading shard axes."""
        return self._sharded(state, jnp.asarray(payloads),
                             jnp.asarray(lengths))

    def stream_fn(self):
        """Jitted entry point with the state carry donated, matching the
        single-device `stack.stream_fn()` discipline."""
        return jax.jit(self._sharded, donate_argnums=(0,))


class ShardedConsole:
    """Per-shard in-band management over a `ShardedStream` state.

    Slices shard ``s``'s state view, runs the ordinary `MgmtConsole`
    operation against it (the command frames traverse that shard's
    compiled pipeline), and scatters the updated state back into the
    stacked tree — so `LOG_READ` / `DROP_READ` / `GROUP_READ` address one
    shard's device tables, and `drain_replica` drains one shard's RSS
    lane without touching its siblings."""

    def __init__(self, stack, shards: int):
        from repro.mgmt.console import MgmtConsole
        self.console = MgmtConsole(stack)
        self.shards = shards

    def on_shard(self, state, s: int, method: str, *args, **kwargs):
        """Run one MgmtConsole method against shard ``s``."""
        if not 0 <= s < self.shards:
            raise IndexError(f"shard {s} out of range "
                             f"(0..{self.shards - 1})")
        view = jax.tree.map(lambda x: x[s], state)
        view, r = getattr(self.console, method)(view, *args, **kwargs)
        state = jax.tree.map(lambda full, new: full.at[s].set(new),
                             state, view)
        return state, r

    # the per-shard addressing surface the operator console uses --------
    def read_counters(self, state, shard: int, tile: str, age: int = 0):
        return self.on_shard(state, shard, "read_counters", tile, age)

    def read_drops(self, state, shard: int, tile: str):
        return self.on_shard(state, shard, "read_drops", tile)

    def read_group(self, state, shard: int, group: str):
        return self.on_shard(state, shard, "read_group", group)

    def drain_replica(self, state, shard: int, group: str, replica: int):
        return self.on_shard(state, shard, "drain_replica", group,
                             replica)

    def restore_replica(self, state, shard: int, group: str,
                        replica: int):
        return self.on_shard(state, shard, "restore_replica", group,
                             replica)

    def dump_counters(self, state, age: int = 0
                      ) -> Tuple[Dict, Dict[int, Dict[str, Dict]]]:
        """Every shard's per-tile counter rows: {shard: {tile: row}}."""
        from repro.core import control
        out: Dict[int, Dict[str, Dict]] = {}
        con = self.console
        tiles = list(con.node_ids)
        for s in range(self.shards):
            view = jax.tree.map(lambda x: x[s], state)
            view, resps = con.roundtrip(view, [
                (control.OP_LOG_READ, 0, con.node_ids[t], age, 0)
                for t in tiles])
            state = jax.tree.map(lambda full, new: full.at[s].set(new),
                                 state, view)
            out[s] = {t: r["row"] for t, r in zip(tiles, resps)
                      if r["status"] == 1}
        return state, out
