"""Vectorized byte-level packet operations.

Payloads are (B, L) uint8 tensors with per-packet valid lengths.  All
helpers are jittable and operate on whole batches — the TPU analog of the
FPGA's per-flit header parse/realign datapath.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# field reads (big-endian network order)


def be16(payload, off):
    """(B, L) uint8, static or (B,) offset -> (B,) uint32."""
    if isinstance(off, int):
        hi = payload[:, off].astype(jnp.uint32)
        lo = payload[:, off + 1].astype(jnp.uint32)
    else:
        hi = jnp.take_along_axis(payload, off[:, None], 1)[:, 0].astype(jnp.uint32)
        lo = jnp.take_along_axis(payload, off[:, None] + 1, 1)[:, 0].astype(jnp.uint32)
    return (hi << 8) | lo


def be32(payload, off):
    if isinstance(off, int):
        b = [payload[:, off + i].astype(jnp.uint32) for i in range(4)]
    else:
        b = [jnp.take_along_axis(payload, off[:, None] + i, 1)[:, 0]
             .astype(jnp.uint32) for i in range(4)]
    return (b[0] << 24) | (b[1] << 16) | (b[2] << 8) | b[3]


def u8(payload, off):
    if isinstance(off, int):
        return payload[:, off].astype(jnp.uint32)
    return jnp.take_along_axis(payload, off[:, None], 1)[:, 0].astype(jnp.uint32)


# ---------------------------------------------------------------------------
# field writes


def set_u8(payload, off: int, val):
    return payload.at[:, off].set(val.astype(jnp.uint8))


def set_be16(payload, off: int, val):
    v = val.astype(jnp.uint32)
    payload = payload.at[:, off].set((v >> 8).astype(jnp.uint8))
    return payload.at[:, off + 1].set((v & 0xFF).astype(jnp.uint8))


def set_be32(payload, off: int, val):
    v = val.astype(jnp.uint32)
    for i, sh in enumerate((24, 16, 8, 0)):
        payload = payload.at[:, off + i].set(((v >> sh) & 0xFF).astype(jnp.uint8))
    return payload


# ---------------------------------------------------------------------------
# header strip / prepend (data realignment)


def shift_left(payload, n, mask=None):
    """Strip n leading bytes per packet (n: static int or (B,) int32)."""
    B, L = payload.shape
    idx = jnp.arange(L)[None, :]
    src = idx + (n if isinstance(n, int) else n[:, None])
    src = jnp.clip(src, 0, L - 1)
    out = jnp.take_along_axis(payload, src.astype(jnp.int32), axis=1)
    keep = src < L
    out = jnp.where(keep, out, 0).astype(jnp.uint8)
    if mask is not None:
        out = jnp.where(mask[:, None], out, payload)
    return out


def shift_right(payload, n, mask=None):
    """Make room for an n-byte header (contents shifted toward the tail)."""
    B, L = payload.shape
    idx = jnp.arange(L)[None, :]
    src = idx - (n if isinstance(n, int) else n[:, None])
    valid = src >= 0
    src = jnp.clip(src, 0, L - 1)
    out = jnp.take_along_axis(payload, src.astype(jnp.int32), axis=1)
    out = jnp.where(valid, out, 0).astype(jnp.uint8)
    if mask is not None:
        out = jnp.where(mask[:, None], out, payload)
    return out


def write_bytes(payload, off: int, data):
    """Write (B, n) bytes at a static offset."""
    n = data.shape[1]
    return jax.lax.dynamic_update_slice(
        payload, data.astype(jnp.uint8), (0, off))


# ---------------------------------------------------------------------------
# RFC 1071 internet checksum


def checksum16(payload, start, length):
    """Ones-complement 16-bit checksum over [start, start+length) per packet.
    start: static int; length: (B,) int32.  Returns (B,) uint32 (already
    complemented, network order)."""
    B, L = payload.shape
    idx = jnp.arange(L - start)
    seg = payload[:, start:].astype(jnp.uint32)
    valid = idx[None, :] < length[:, None]
    seg = jnp.where(valid, seg, 0)
    if seg.shape[1] % 2:
        seg = jnp.pad(seg, ((0, 0), (0, 1)))
    words = (seg[:, 0::2] << 8) | seg[:, 1::2]
    total = words.sum(axis=1, dtype=jnp.uint32)
    for _ in range(3):                       # fold carries
        total = (total & 0xFFFF) + (total >> 16)
    return (~total) & jnp.uint32(0xFFFF)


def pseudo_header_sum(src_ip, dst_ip, proto, tcp_len):
    """IPv4 pseudo-header contribution for UDP/TCP checksums (unfolded)."""
    s = (src_ip >> 16) + (src_ip & 0xFFFF)
    s = s + (dst_ip >> 16) + (dst_ip & 0xFFFF)
    s = s + proto.astype(jnp.uint32) + tcp_len.astype(jnp.uint32)
    return s


def checksum16_with_pseudo(payload, start, length, pseudo):
    """Checksum including a pseudo-header partial sum."""
    B, L = payload.shape
    idx = jnp.arange(L - start)
    seg = payload[:, start:].astype(jnp.uint32)
    valid = idx[None, :] < length[:, None]
    seg = jnp.where(valid, seg, 0)
    if seg.shape[1] % 2:
        seg = jnp.pad(seg, ((0, 0), (0, 1)))
    words = (seg[:, 0::2] << 8) | seg[:, 1::2]
    total = words.sum(axis=1, dtype=jnp.uint32) + pseudo.astype(jnp.uint32)
    for _ in range(3):
        total = (total & 0xFFFF) + (total >> 16)
    return (~total) & jnp.uint32(0xFFFF)


# ---------------------------------------------------------------------------
# numpy-side golden frame builders (for tests/benchmarks; Linux wire format)


def np_checksum16(data: bytes) -> int:
    import numpy as np
    b = np.frombuffer(data, dtype=np.uint8).astype(np.uint32)
    if len(b) % 2:
        b = np.append(b, 0)
    total = int(((b[0::2] << 8) | b[1::2]).sum())
    while total >> 16:
        total = (total & 0xFFFF) + (total >> 16)
    return (~total) & 0xFFFF
