"""UDP tile: parse + (optional) checksum verify on RX, build on TX."""
from __future__ import annotations

import jax.numpy as jnp

from repro.net import bytesops as B
from repro.net.ipv4 import PROTO_UDP

UDP_HLEN = 8


def parse(payload, length, meta):
    """Returns (stripped, new_length, meta', ok)."""
    return parse_ex(payload, length, meta)[:4]


def parse_ex(payload, length, meta):
    """`parse` plus a per-packet drop-reason code (repro.obs.reasons):
    the runt check is attributed first (it poisons everything after),
    then the length-vs-IP check, then the checksum."""
    from repro.obs import reasons as R
    src_port = B.be16(payload, 0)
    dst_port = B.be16(payload, 2)
    udp_len = B.be16(payload, 4)
    csum = B.be16(payload, 6)
    pseudo = B.pseudo_header_sum(meta["src_ip"], meta["dst_ip"],
                                 jnp.full_like(meta["src_ip"], PROTO_UDP),
                                 udp_len)
    full = B.checksum16_with_pseudo(payload, 0, udp_len.astype(jnp.int32),
                                    pseudo)
    ok_csum = (csum == 0) | (full == 0)    # csum 0 = disabled (RFC 768)
    ok_len = udp_len.astype(jnp.int32) <= length
    # runt header: udp_len < 8 would yield a negative payload length that
    # poisons every downstream length computation — reject AND clamp
    ok_runt = udp_len.astype(jnp.int32) >= UDP_HLEN
    ok = ok_csum & ok_len & ok_runt
    reason = jnp.where(
        ~ok_runt, R.RUNT_UDP,
        jnp.where(~ok_len, R.UDP_LEN,
                  jnp.where(~ok_csum, R.UDP_CSUM, R.NONE)))
    stripped = B.shift_left(payload, UDP_HLEN)
    m = dict(meta)
    m.update({"src_port": src_port, "dst_port": dst_port,
              "udp_len": udp_len})
    plen = jnp.maximum(udp_len.astype(jnp.int32) - UDP_HLEN, 0)
    return stripped, plen, m, ok, reason.astype(jnp.int32)


def build(payload, length, meta, with_checksum: bool = True):
    """Prepend a UDP header; meta ports are already reply-oriented."""
    out = B.shift_right(payload, UDP_HLEN)
    ulen = (length + UDP_HLEN).astype(jnp.uint32)
    out = B.set_be16(out, 0, meta["src_port"])
    out = B.set_be16(out, 2, meta["dst_port"])
    out = B.set_be16(out, 4, ulen)
    out = B.set_be16(out, 6, jnp.zeros_like(ulen))
    if with_checksum:
        pseudo = B.pseudo_header_sum(meta["src_ip"], meta["dst_ip"],
                                     jnp.full_like(meta["src_ip"], PROTO_UDP),
                                     ulen)
        csum = B.checksum16_with_pseudo(out, 0, ulen.astype(jnp.int32), pseudo)
        csum = jnp.where(csum == 0, jnp.uint32(0xFFFF), csum)
        out = B.set_be16(out, 6, csum)
    return out, length + UDP_HLEN
