"""Operate a running stack over the in-band management plane (paper §3.6,
§4.6): no rebuilds, no direct state pokes — every operation below is a
standard UDP frame through the compiled pipeline, every answer an in-band
reply frame.

  1. serve echo traffic on a NAT'd virtual IP,
  2. read every tile's telemetry counters over the management port,
  3. live-rewrite the NAT mapping (migration-style) and keep serving,
  4. drain one echo replica for maintenance, prove dispatch avoids it,
     then restore it,
  5. poll the version counter to confirm convergence,
  6. watch: install an SLO rule on the drop rate, push a loss burst
     through, catch the device-emitted MSG_ALERT frame, and read the
     per-window series ring back over the same management port.

Run:  PYTHONPATH=src python examples/operate.py
"""
import jax.numpy as jnp
import numpy as np

from repro.apps import echo
from repro.mgmt.console import MgmtConsole, dump_counters
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, udp_topology_with_nat
from repro.obs import collector, slo

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
VIP, VIP2 = F.ip("20.0.0.9"), F.ip("20.0.0.7")
MGMT_PORT = 9909


def traffic(stack, state, dst_ip, n=4, tag=b"ping"):
    frames = [F.udp_rpc_frame(IP_C, dst_ip, 5000 + i, 7,
                              rpc.np_frame(rpc.MSG_ECHO, i, tag))
              for i in range(n)]
    payload, length = F.to_batch(frames, 256)
    state, q, ql, alive, info = stack.rx_tx(
        state, jnp.asarray(payload), jnp.asarray(length))
    served = int(np.asarray(info["echo"]).sum())
    print(f"  [data] {n} frames -> {dst_ip:#010x}: {served} served, "
          f"{int(np.asarray(alive).sum())} alive")
    return state


def main():
    apps = [echo.make(port=7, n_replicas=2)]
    topo = udp_topology_with_nat(apps)
    slo.bind_watchdog(topo, collector_ip=IP_C)     # in-band SLO alerts
    stack = UdpStack(apps, IP_S, topo=topo,
                     nat_entries=[(VIP, IP_S)], mgmt_port=MGMT_PORT)
    state = stack.init_state()
    con = MgmtConsole(stack)
    print("[topology] data pipeline:", " -> ".join(stack.pipeline.order))
    print("[topology] ctrl NoC:     ", " -> ".join(stack.ctrl_pipe.order))

    print("\n-- 1. serve on the virtual IP")
    state = traffic(stack, state, VIP)

    # age 0 = the newest *completed* batch (the traffic above): the fused
    # node append lands at batch egress, so readback serves rows through
    # the previous batch
    print("\n-- 2. telemetry readback (LOG_READ per tile, age=0)")
    state, counters = dump_counters(stack, state, age=0)
    print(f"  {'tile':<12} {'step':>5} {'pkts_in':>8} {'drops':>6} "
          f"{'noc_lat':>8}")
    for tile, row in counters.items():
        print(f"  {tile:<12} {row['step']:>5} {row['packets_in']:>8} "
              f"{row['drops']:>6} {row['noc_latency']:>8}")

    print("\n-- 3. live NAT rewrite: move the service to a new virtual IP")
    state, ack = con.set_nat(state, 0, VIP2, IP_S)
    print(f"  [mgmt] NAT_SET acked: status={ack['status']} "
          f"version={ack['version']}")
    state = traffic(stack, state, VIP2, tag=b"post-migrate")

    print("\n-- 4. drain replica 0 for maintenance")
    state, ack = con.drain_replica(state, "echo", 0)
    print(f"  [mgmt] HEALTH_SET acked: version={ack['version']}")
    state = traffic(stack, state, VIP2, n=6)
    served = np.asarray(state["apps"]["echo"]["served"])
    print(f"  [state] served per replica: {served.tolist()} "
          f"(replica 0 drained)")
    state, ack = con.restore_replica(state, "echo", 0)
    state = traffic(stack, state, VIP2, n=6)
    served2 = np.asarray(state["apps"]["echo"]["served"])
    print(f"  [state] served per replica: {served2.tolist()} (restored)")

    print("\n-- 5. convergence")
    state, converged = con.wait_converged(state, 3)
    state, v = con.version(state)
    print(f"  [mgmt] version={v} converged={converged}")

    print("\n-- 6. watch: SLO rule on the ip_rx drop rate")
    state, ack = con.set_window(state, 1)          # 1 batch per window
    state, ack = con.set_slo(state, 0, "drops", "ip_rx",
                             raise_thr=3, clear_thr=1)
    print(f"  [mgmt] SLO_SET acked: status={ack['status']} "
          f"(drops@ip_rx raise>=3 clear<=1, window=1 batch)")

    def burst(n, corrupt):
        out = []
        for i in range(n):
            fr = F.udp_rpc_frame(IP_C, VIP2, 6000 + i, 7,
                                 rpc.np_frame(rpc.MSG_ECHO, i, b"watch"))
            if corrupt:
                fr = bytearray(fr)
                fr[F.l2_offset(bytes(fr)) + 10] ^= 0xFF   # break IP csum
                fr = bytes(fr)
            out.append(fr)
        return out

    batches = [burst(4, False), burst(4, True), burst(4, False)]
    arena = F.FrameArena(len(batches), 4, 256)
    arena.fill([f for b in batches for f in b])
    state, outs = stack.run_stream(state, jnp.asarray(arena.payload),
                                   jnp.asarray(arena.length))
    for b in range(len(batches)):
        fired = np.flatnonzero(np.asarray(outs["alert_valid"])[b])
        print(f"  [watch] batch {b}: "
              f"{'ALERT rule ' + str(fired.tolist()) if fired.size else 'ok'}")
    alerts = [collector.decode_alert(f) for f in collector.harvest(
        outs["alert_payload"], outs["alert_len"], outs["alert_valid"])]
    for a in alerts:
        print(f"  [alert] {a['metric']} node={a['node']} "
              f"value={a['value']} >= {a['threshold']} "
              f"(window {a['window']}) — edge-triggered, one per burst")

    state, r = con.read_series(state, "ip_rx", age=0)
    s = r["series"]
    print(f"  [series] ip_rx newest window: frames={s['frames']} "
          f"drops={s['drops']} bytes={s['bytes']} "
          f"occ_p99_bucket={s['occ_p99']} ({s['windows']} windows closed)")


if __name__ == "__main__":
    main()
