"""Operate a running stack over the in-band management plane (paper §3.6,
§4.6): no rebuilds, no direct state pokes — every operation below is a
standard UDP frame through the compiled pipeline, every answer an in-band
reply frame.

  1. serve echo traffic on a NAT'd virtual IP,
  2. read every tile's telemetry counters over the management port,
  3. live-rewrite the NAT mapping (migration-style) and keep serving,
  4. drain one echo replica for maintenance, prove dispatch avoids it,
     then restore it,
  5. poll the version counter to confirm convergence.

Run:  PYTHONPATH=src python examples/operate.py
"""
import jax.numpy as jnp
import numpy as np

from repro.apps import echo
from repro.mgmt.console import MgmtConsole, dump_counters
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, udp_topology_with_nat

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
VIP, VIP2 = F.ip("20.0.0.9"), F.ip("20.0.0.7")
MGMT_PORT = 9909


def traffic(stack, state, dst_ip, n=4, tag=b"ping"):
    frames = [F.udp_rpc_frame(IP_C, dst_ip, 5000 + i, 7,
                              rpc.np_frame(rpc.MSG_ECHO, i, tag))
              for i in range(n)]
    payload, length = F.to_batch(frames, 256)
    state, q, ql, alive, info = stack.rx_tx(
        state, jnp.asarray(payload), jnp.asarray(length))
    served = int(np.asarray(info["echo"]).sum())
    print(f"  [data] {n} frames -> {dst_ip:#010x}: {served} served, "
          f"{int(np.asarray(alive).sum())} alive")
    return state


def main():
    apps = [echo.make(port=7, n_replicas=2)]
    stack = UdpStack(apps, IP_S, topo=udp_topology_with_nat(apps),
                     nat_entries=[(VIP, IP_S)], mgmt_port=MGMT_PORT)
    state = stack.init_state()
    con = MgmtConsole(stack)
    print("[topology] data pipeline:", " -> ".join(stack.pipeline.order))
    print("[topology] ctrl NoC:     ", " -> ".join(stack.ctrl_pipe.order))

    print("\n-- 1. serve on the virtual IP")
    state = traffic(stack, state, VIP)

    # age 0 = the newest *completed* batch (the traffic above): the fused
    # node append lands at batch egress, so readback serves rows through
    # the previous batch
    print("\n-- 2. telemetry readback (LOG_READ per tile, age=0)")
    state, counters = dump_counters(stack, state, age=0)
    print(f"  {'tile':<12} {'step':>5} {'pkts_in':>8} {'drops':>6} "
          f"{'noc_lat':>8}")
    for tile, row in counters.items():
        print(f"  {tile:<12} {row['step']:>5} {row['packets_in']:>8} "
              f"{row['drops']:>6} {row['noc_latency']:>8}")

    print("\n-- 3. live NAT rewrite: move the service to a new virtual IP")
    state, ack = con.set_nat(state, 0, VIP2, IP_S)
    print(f"  [mgmt] NAT_SET acked: status={ack['status']} "
          f"version={ack['version']}")
    state = traffic(stack, state, VIP2, tag=b"post-migrate")

    print("\n-- 4. drain replica 0 for maintenance")
    state, ack = con.drain_replica(state, "echo", 0)
    print(f"  [mgmt] HEALTH_SET acked: version={ack['version']}")
    state = traffic(stack, state, VIP2, n=6)
    served = np.asarray(state["apps"]["echo"]["served"])
    print(f"  [state] served per replica: {served.tolist()} "
          f"(replica 0 drained)")
    state, ack = con.restore_replica(state, "echo", 0)
    state = traffic(stack, state, VIP2, n=6)
    served2 = np.asarray(state["apps"]["echo"]["served"])
    print(f"  [state] served per replica: {served2.tolist()} (restored)")

    print("\n-- 5. convergence")
    state, converged = con.wait_converged(state, 3)
    state, v = con.version(state)
    print(f"  [mgmt] version={v} converged={converged}")


if __name__ == "__main__":
    main()
