"""End-to-end training driver: train a ~100M-param qwen-family model for a
few hundred steps with checkpoint/restart fault tolerance.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(A ~100M model on CPU takes a while; --steps 30 for a quick look.)
"""
import argparse

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.models import model
from repro.models.config import reduced
from repro.optim import adamw
from repro.train.trainer import TrainConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M params: qwen1.5 geometry scaled to d=512, 8 layers, vocab 32k
    cfg = reduced(get_config("qwen1.5-0.5b"), name="qwen-100m",
                  n_layers=8, d_model=512, n_heads=8, n_kv_heads=8,
                  head_dim=64, d_ff=2048, vocab=32768, remat=False)
    n = model.count_params(cfg)
    print(f"[model] {cfg.name}: {n/1e6:.1f}M params")

    tr = Trainer(
        cfg,
        TrainConfig(total_steps=args.steps, ckpt_every=50, log_every=10,
                    ckpt_dir="artifacts/train_lm_ckpt",
                    opt=adamw.AdamWConfig(lr=6e-4, warmup_steps=20,
                                          total_steps=args.steps)),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch))
    tr.install_signal_handlers()           # SIGTERM -> grace checkpoint
    if tr.restore():
        print(f"[resume] from step {tr.step}")
    out = tr.run()
    for m in out["log"]:
        print(f"  step {m['step']:4d} loss {m['loss']:.4f} "
              f"lr {m['lr']:.2e} |grad| {m['grad_norm']:.3f} "
              f"({m['wall_s']}s)")
    print(f"[done] {out['final_step']} steps")


if __name__ == "__main__":
    main()
