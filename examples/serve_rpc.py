"""End-to-end driver: serve a small LM with batched requests behind the
full Beehive network stack (the paper's direct-attached deployment).

Unmodified clients build standard Ethernet/IPv4/UDP frames carrying RPC
requests; the stack parses them on-device, the flow-hash dispatch pins each
session to an engine replica, the LM generates, and replies flow back down
the TX chain.  Midway, one session is live-migrated between engines —
Beehive's TCP-migration use case with the KV cache as connection state.

Run:  PYTHONPATH=src python examples/serve_rpc.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.lm_server import (LmServerApp, decode_reply, encode_request)
from repro.configs import get_smoke_config
from repro.core.routing import fnv1a
from repro.models import model
from repro.net import eth, frames as F, ipv4, rpc, udp
from repro.serve.engine import ServeEngine

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
PORT = 9500


def parse_rx(payload, length):
    p, l, m = eth.parse(payload, length)
    p, l, m2, ok1 = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, ok2 = udp.parse(p, l, m)
    body, blen, rmeta, ok3 = rpc.parse(p, l)
    m3.update(rmeta)
    return body, blen, m3, ok1 & ok2 & ok3


def main():
    cfg = get_smoke_config("internlm2-1.8b")
    params = model.init_params(cfg, jax.random.key(0))
    engines = [LmServerApp(ServeEngine(cfg, params, max_sessions=4,
                                       max_seq=64)) for _ in range(2)]

    # ---- clients: standard frames, one session each ------------------------
    sessions = {101: [5, 6, 7], 102: [9, 8, 7, 6], 103: [3, 1, 4, 1, 5]}
    t0 = time.time()
    transcript = {}
    for round_ in range(3):
        frames = [F.udp_rpc_frame(IP_C, IP_S, 4000 + s % 7, PORT,
                                  rpc.np_frame(rpc.MSG_LM_GENERATE, s,
                                               encode_request(s, 4, toks)))
                  for s, toks in sessions.items()]
        payload, length = F.to_batch(frames, 512)
        body, blen, m, ok = parse_rx(jnp.asarray(payload),
                                     jnp.asarray(length))
        assert bool(ok.all())
        # flow-hash dispatch pins a session to an engine (Beehive scale-out)
        h = np.asarray(fnv1a([m["src_ip"], m["dst_ip"], m["src_port"],
                              m["dst_port"]])) % len(engines)
        for i, (s, toks) in enumerate(sessions.items()):
            req = bytes(np.asarray(body[i, :blen[i]]).tobytes())
            reply = engines[h[i]].handle(req)
            sid, out_toks, ok = decode_reply(reply)
            assert ok
            transcript.setdefault(s, []).extend(out_toks)
        if round_ == 0:
            # live migration: move session 101 to the other engine;
            # the dispatch table would be rewritten by the control plane
            src = engines[h[0]]
            dst = engines[1 - h[0]]
            src.migrate_session_to(101, dst)
            engines_for_101 = dst
            print(f"[migrate] session 101 moved engine{h[0]} -> "
                  f"engine{1 - h[0]} (KV cache + position serialized)")
            h[0] = 1 - h[0]
        sessions = {s: [] or list(transcript[s][-1:]) for s in sessions}
        # follow-up requests continue each session with its last token
        sessions = {s: [transcript[s][-1]] for s in transcript}

    dt = time.time() - t0
    for s, toks in transcript.items():
        print(f"[session {s}] {len(toks)} tokens: {toks}")
    print(f"[serve_rpc] 3 rounds x 3 sessions in {dt:.1f}s "
          f"(stack parse + flow-hash dispatch + LM decode + migration)")


if __name__ == "__main__":
    main()
