"""The paper's throughput use case: Reed-Solomon (8,2) erasure coding as a
scale-out application behind the UDP stack (paper §5.1 / Table 2).

Sends 4 KiB storage blocks from a simulated client, encodes them on 1..4
replicated RS tiles (round-robin dispatch), verifies the parity against
the GF(256) oracle, and demonstrates recovery of two erased shards.

Run:  PYTHONPATH=src python examples/erasure_coding.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import reed_solomon
from repro.kernels.rs_encode import gf
from repro.kernels.rs_encode.ref import rs_encode_np
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")


def gf_solve(A, b):
    """Solve A x = b over GF(256) (Gaussian elimination)."""
    A = [[int(v) for v in row] for row in A]
    b = [row[:] for row in b]
    n = len(A)
    for c in range(n):
        piv = next(i for i in range(c, n) if A[i][c])
        A[c], A[piv] = A[piv], A[c]
        b[c], b[piv] = b[piv], b[c]
        inv = gf.gf_inv(A[c][c])
        A[c] = [gf.gf_mul(v, inv) for v in A[c]]
        b[c] = [gf.gf_mul(v, inv) for v in b[c]]
        for i in range(n):
            if i != c and A[i][c]:
                f = A[i][c]
                A[i] = [v ^ gf.gf_mul(f, w) for v, w in zip(A[i], A[c])]
                b[i] = [v ^ gf.gf_mul(f, w) for v, w in zip(b[i], b[c])]
    return b


def main():
    stack = UdpStack([reed_solomon.make(port=9000, n_replicas=4)], IP_S)
    state = stack.init_state()
    rng = np.random.default_rng(42)
    blocks = [rng.integers(0, 256, 4096, dtype=np.uint8) for _ in range(8)]
    frames = [F.udp_rpc_frame(IP_C, IP_S, 5000 + i, 9000,
                              rpc.np_frame(rpc.MSG_RS_ENCODE, i,
                                           b.tobytes()))
              for i, b in enumerate(blocks)]
    payload, length = F.to_batch(frames, 4400)
    state, q, ql, alive, _ = jax.jit(stack.rx_tx)(
        state, jnp.asarray(payload), jnp.asarray(length))
    print(f"[stack] {int(alive.sum())}/8 blocks encoded; replica ops = "
          f"{np.asarray(state['apps']['rs']['ops']).tolist()} (round-robin)")

    # verify + erase-and-recover for block 0
    from repro.net import eth, ipv4, udp
    p, l, m = eth.parse(q, ql)
    p, l, m2, _ = ipv4.parse(p, l)
    m.update(m2)
    p, l, m3, _ = udp.parse(p, l, m)
    body, blen, _, _ = rpc.parse(p, l)
    parity = np.asarray(body[0, :1024]).reshape(2, 512)
    data = blocks[0].reshape(8, 512)
    gm = gf.generator_matrix(8, 2)
    np.testing.assert_array_equal(parity, rs_encode_np(data, gm))
    print("[verify] parity matches GF(256) oracle")

    # erase shards 2 and 5; reconstruct from the other 6 + both parities
    full = np.vstack([np.eye(8, dtype=np.uint8), gm])
    shards = np.vstack([data, parity])
    keep = [0, 1, 3, 4, 6, 7, 8, 9]
    rec = gf_solve(full[keep].tolist(), shards[keep].tolist())
    np.testing.assert_array_equal(np.asarray(rec, np.uint8), data)
    print("[recover] two erased shards reconstructed exactly "
          "(double-fault tolerance, paper's (8,2) configuration)")


if __name__ == "__main__":
    main()
