"""Quickstart: the Beehive-JAX public API in one file.

1. Declare a topology (tiles + chains), validate + deadlock-check it.
2. Run golden UDP frames from an unmodified "Linux client" through the
   jitted stack to a replicated echo app and back.
3. Train a small LM for a few steps and serve it.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import echo
from repro.configs import get_smoke_config
from repro.core import analyze
from repro.data.pipeline import DataConfig
from repro.models import model
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack
from repro.optim import adamw
from repro.serve.engine import ServeEngine
from repro.train.trainer import TrainConfig, Trainer

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")


def main():
    # --- 1. the network stack as a composable topology ---------------------
    stack = UdpStack([echo.make(port=7, n_replicas=2)], IP_S)
    report = analyze(stack.topo)
    print(f"[topology] {len(stack.topo.tiles)} tiles, "
          f"{len(stack.topo.chains)} chains, deadlock: {report.summary()}")

    # --- 2. packets through the stack --------------------------------------
    frames = [F.udp_rpc_frame(IP_C, IP_S, 5000 + i, 7,
                              rpc.np_frame(rpc.MSG_ECHO, i,
                                           f"hello-{i}".encode()))
              for i in range(4)]
    payload, length = F.to_batch(frames)
    state = stack.init_state()
    state, q, ql, alive, _ = jax.jit(stack.rx_tx)(
        state, jnp.asarray(payload), jnp.asarray(length))
    print(f"[stack] {int(alive.sum())}/4 packets echoed; per-replica "
          f"served = {np.asarray(state['apps']['echo']['served']).tolist()}")

    # --- 3. train a small model, then serve it -----------------------------
    cfg = get_smoke_config("qwen1.5-0.5b")
    tr = Trainer(cfg,
                 TrainConfig(total_steps=20, ckpt_every=10, log_every=5,
                             ckpt_dir="artifacts/quickstart_ckpt",
                             opt=adamw.AdamWConfig(lr=3e-3, warmup_steps=5,
                                                   total_steps=20)),
                 DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
    out = tr.run()
    print(f"[train] loss {out['log'][0]['loss']:.3f} -> "
          f"{out['log'][-1]['loss']:.3f} in {out['final_step']} steps")

    eng = ServeEngine(cfg, tr.params, max_sessions=2, max_seq=48)
    sid = eng.new_session(np.asarray([5, 6, 7, 8], np.int32))
    toks = eng.generate(sid, 8)
    print(f"[serve] generated tokens: {toks}")


if __name__ == "__main__":
    main()
