"""Diagnose a direct-attached RPC serving stack with the device-resident
observability layer: flight recorder, drop-reason attribution, latency
histograms — then export the recording as a Perfetto trace.

The stack under observation is the paper's serving path: an
`rpc_serve_topology` dispatching `MSG_RS_ENCODE` requests to the
`rs_serve` accelerator tile (Reed-Solomon parity computed in the reply
path, no host round trip).  Everything below is in-band and
device-resident: the recorder is switched on over the management port (a
standard UDP frame through the compiled pipeline), the per-frame trace
rows, drop tables and histograms accumulate *inside* the `run_stream`
scan with zero host callbacks, and the only host work is the final
readback + rendering.

  1. enable the flight recorder live (TRACE_SET — no retrace),
  2. stream an RS-encode request window that includes misbehaving frames,
  3. read the drop-reason tables over the management port (DROP_READ),
  4. read occupancy histograms (HISTO_READ) and print p50/p99,
  5. print the `top`-style panel and write a Chrome/Perfetto trace of
     the serve path (open artifacts/diagnose.perfetto.json at
     ui.perfetto.dev).

Run:  PYTHONPATH=src python examples/diagnose.py
"""
import os

import jax.numpy as jnp
import numpy as np

from repro.mgmt.console import MgmtConsole
from repro.net import frames as F, rpc
from repro.net.stack import UdpStack, rpc_serve_topology
from repro.obs import export, flight

IP_C, IP_S = F.ip("10.0.0.2"), F.ip("10.0.0.1")
SERVE_PORT, MGMT_PORT = 9400, 9909
BLOCK = 4096                    # rs_serve data block: 8 x 512 bytes
WIDTH = 4400
OUT = os.path.join("artifacts", "diagnose.perfetto.json")


def rs_frame(req_id, body):
    return F.udp_rpc_frame(IP_C, IP_S, 5000 + req_id, SERVE_PORT,
                           rpc.np_frame(rpc.MSG_RS_ENCODE, req_id, body))


def broken_frames(rng):
    """Three frames a real deployment would throw at you: a runt UDP
    header, a corrupted IP checksum, and a truncated RS request that
    parses fine but is rejected by the app tile itself."""
    runt = bytearray(rs_frame(98, rng.bytes(BLOCK)))
    off = F.l2_offset(bytes(runt)) + 20 + 4
    runt[off:off + 2] = (4).to_bytes(2, "big")      # udp_len < 8
    corrupt = bytearray(rs_frame(99, rng.bytes(BLOCK)))
    corrupt[F.l2_offset(bytes(corrupt)) + 10] ^= 0xFF
    return [bytes(runt), bytes(corrupt), rs_frame(97, b"short")]


def main():
    stack = UdpStack([], IP_S, mgmt_port=MGMT_PORT,
                     topo=rpc_serve_topology(
                         [("rs", "rs_serve", rpc.MSG_RS_ENCODE)]))
    state = stack.init_state()
    con = MgmtConsole(stack)
    print("[topology]", " -> ".join(stack.pipeline.order))

    print("\n-- 1. enable the flight recorder (sample every frame)")
    state, r = con.set_trace(state, True, shift=0)
    print(f"  TRACE_SET: status={r['status']} version={r['version']} "
          f"(runtime state — live next batch, no retrace)")

    print("\n-- 2. stream RS-encode requests, three bad frames mixed in")
    rng = np.random.default_rng(7)
    n_batches, batch = 4, 4
    frames = [rs_frame(i, rng.bytes(BLOCK))
              for i in range(n_batches * batch - 3)]
    frames += broken_frames(rng)
    arena = F.FrameArena(n_batches, batch, WIDTH)
    arena.fill(frames)
    state, outs = stack.stream_fn()(state, jnp.asarray(arena.payload),
                                    jnp.asarray(arena.length))
    alive = np.asarray(outs["alive"])
    print(f"  {alive.size} frames streamed, {int(alive.sum())} replied, "
          f"{int((~alive).sum())} dropped in the pipeline")

    print("\n-- 3. why were they dropped? (DROP_READ per tile)")
    for tile in ("ip_rx", "udp_rx", "rs"):
        state, r = con.read_drops(state, tile)
        print(f"  {tile:<8} {r.get('reasons', {})}")

    print("\n-- 4. where does the time go? (HISTO_READ)")
    state, r = con.read_histo(state, "rs")
    p50 = flight.percentile(r["table_row"], 0.50)
    p99 = flight.percentile(r["table_row"], 0.99)
    print(f"  rs occupancy:  p50<={p50} p99<={p99} cycles "
          f"(~{sum(r['table_row'])} frames histogrammed)")
    state, r = con.read_histo(state)                # end-to-end row
    print(f"  end-to-end:    p50<={flight.percentile(r['table_row'], .5)}"
          f" p99<={flight.percentile(r['table_row'], .99)} cycles")

    print("\n-- 5. the top-style panel + Perfetto export")
    print(export.summary(state, stack.pipeline))
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    n = export.write_perfetto(OUT, state, stack.pipeline)
    print(f"\n  wrote {n} trace events to {OUT} "
          f"(open at ui.perfetto.dev or chrome://tracing)")


if __name__ == "__main__":
    main()
